//! Arithmetic substrates for the prediction stage.
//!
//! * [`fixed`] — symmetric integer quantization (INT4/8/16) used for the
//!   low-precision pre-compute stage and the INT16 formal-compute baseline.
//! * [`lz`] — the leading-zero codec: `x = sign · M · 2^(W-LZ)` (Eq. 3).
//! * [`dlzs`] — the paper's Differential Leading-Zero Scheme and the
//!   symmetric baseline (SLZS, as used by FACT), both multiplier-free, plus
//!   the PSP pre-flipping model.
//! * [`opcount`] — operation accounting and the equivalent-additions
//!   normalization (α..ε = 1, 3, 1, 8, 25) from the paper's footnote 1.
//! * [`lanes`] — the portable 8-wide SIMD layer the hot buffer-writing
//!   kernels are spelled in ([`KernelPath`] dispatch, [`ReductionOrder`]
//!   bit-identity contract; DESIGN.md §10).

pub mod dlzs;
pub mod fixed;
pub mod lanes;
pub mod lz;
pub mod opcount;

pub use dlzs::{dlzs_mul, slzs_mul, LzWeight};
pub use fixed::{
    quantize_row, quantize_row_into, quantize_row_into_with, truncate_msb, IntBits, QuantMat,
};
pub use lanes::{F32x8, I64x8, KernelPath, ReductionOrder, LANES};
pub use lz::{lz_count, LzCode};
pub use opcount::{EquivWeights, OpCounter, OpKind};
