//! Vanilla dense attention — the paper's baseline and the numerical oracle.
//!
//! Materializes the full attention matrix `A = QKᵀ·scale`, applies the
//! row-wise stable softmax of Eq. (1), then multiplies by V (Eq. 2). Op
//! accounting follows the paper's convention: the row max costs S−1
//! comparisons, the sum S−1 additions, normalization one division per
//! element.

use super::{AttnInputs, Selection};
use crate::arith::{OpCounter, OpKind};
use crate::tensor::Mat;

/// Dense attention with op accounting. Traffic model: Q, K, V are each read
/// from DRAM once; the T×S attention matrix spills to DRAM (write + read)
/// when it exceeds `sram_budget` bytes — the row-dependency problem of
/// Sec. III-A(2).
pub fn dense_attention(inp: &AttnInputs, sram_budget: usize, c: &mut OpCounter) -> Mat {
    let (t, s, d) = (inp.t(), inp.s(), inp.d());

    // A = Q Kᵀ · scale
    let mut a = inp.q.matmul(&inp.k.transpose());
    a.scale(inp.scale);
    c.tally(OpKind::Mul, (t * s * d) as u64 + (t * s) as u64); // QKᵀ + scale
    c.tally(OpKind::Add, (t * s * (d - 1)) as u64);

    // Traffic: operands in, scores spill if they don't fit on chip.
    let f = 4u64; // f32 bytes
    c.dram(f * (t * d + 2 * s * d) as u64); // Q, K, V loads
    let score_bytes = f * (t * s) as u64;
    if score_bytes as usize > sram_budget {
        c.dram(2 * score_bytes); // write A out, read it back for softmax/AV
    } else {
        c.sram(2 * score_bytes);
    }

    // Row-wise softmax (Eq. 1).
    let p = a.softmax_rows();
    c.tally(OpKind::Cmp, (t * (s - 1)) as u64); // row max
    c.tally(OpKind::Add, (t * s) as u64); // subtract max (counted as adds)
    c.tally(OpKind::Exp, (t * s) as u64);
    c.tally(OpKind::Add, (t * (s - 1)) as u64); // denominator sum
    c.tally(OpKind::Div, (t * s) as u64); // normalize

    // O = P V
    let o = p.matmul(inp.v);
    c.tally(OpKind::Mul, (t * s * d) as u64);
    c.tally(OpKind::Add, (t * (s - 1) * d) as u64);
    c.dram(f * (t * d) as u64); // store O

    o
}

/// Oracle for *selected* attention: softmax over exactly the keys in
/// `sel.rows[i]` (all other logits = −∞), then multiply by V. This is what
/// SU-FA must reproduce bit-for-bit (up to fp association) — used heavily
/// in tests. No op accounting: oracles are free.
pub fn masked_attention_oracle(inp: &AttnInputs, sel: &Selection) -> Mat {
    let (t, d) = (inp.t(), inp.d());
    assert_eq!(sel.rows.len(), t);
    sel.assert_in_range(inp.s());
    let mut out = Mat::zeros(t, d);
    for i in 0..t {
        let keys = &sel.rows[i];
        if keys.is_empty() {
            continue;
        }
        // Logits for selected keys.
        let mut logits: Vec<f32> = keys
            .iter()
            .map(|&j| {
                let mut dot = 0.0f32;
                for p in 0..d {
                    dot += inp.q.at(i, p) * inp.k.at(j, p);
                }
                dot * inp.scale
            })
            .collect();
        crate::tensor::softmax_inplace(&mut logits);
        for (w, &j) in logits.iter().zip(keys) {
            for p in 0..d {
                *out.at_mut(i, p) += w * inp.v.at(j, p);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_inputs(t: usize, s: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        (
            Mat::randn(t, d, 1.0, &mut rng),
            Mat::randn(s, d, 1.0, &mut rng),
            Mat::randn(s, d, 1.0, &mut rng),
        )
    }

    #[test]
    fn dense_matches_masked_oracle_with_full_selection() {
        let (q, k, v) = rand_inputs(5, 9, 8, 1);
        let inp = AttnInputs::new(&q, &k, &v);
        let mut c = OpCounter::new();
        let dense = dense_attention(&inp, usize::MAX, &mut c);
        let oracle = masked_attention_oracle(&inp, &Selection::full(5, 9));
        assert!(dense.max_abs_diff(&oracle) < 1e-5);
    }

    #[test]
    fn rows_of_output_are_convex_combos() {
        // With V = identity-ish columns the output row must be a convex
        // combination of V rows: check total weight 1 via ones-V.
        let (q, k, _) = rand_inputs(4, 7, 8, 2);
        let ones = Mat::from_fn(7, 8, |_, _| 1.0);
        let inp = AttnInputs::new(&q, &k, &ones);
        let mut c = OpCounter::new();
        let o = dense_attention(&inp, usize::MAX, &mut c);
        for i in 0..o.rows {
            for j in 0..o.cols {
                assert!((o.at(i, j) - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn op_counts_match_formulas() {
        let (q, k, v) = rand_inputs(3, 10, 4, 3);
        let inp = AttnInputs::new(&q, &k, &v);
        let mut c = OpCounter::new();
        dense_attention(&inp, usize::MAX, &mut c);
        let (t, s, d) = (3u64, 10u64, 4u64);
        assert_eq!(c.exp, t * s);
        assert_eq!(c.cmp, t * (s - 1));
        assert_eq!(c.div, t * s);
        assert_eq!(c.mul, t * s * d + t * s + t * s * d);
    }

    #[test]
    fn score_spill_charged_only_when_over_budget() {
        let (q, k, v) = rand_inputs(8, 64, 16, 4);
        let inp = AttnInputs::new(&q, &k, &v);
        let mut small = OpCounter::new();
        dense_attention(&inp, 16, &mut small); // tiny SRAM: must spill
        let mut big = OpCounter::new();
        dense_attention(&inp, usize::MAX, &mut big);
        assert!(small.dram_bytes > big.dram_bytes);
        let spill = 2 * 4 * 8 * 64;
        assert_eq!(small.dram_bytes - big.dram_bytes, spill);
    }

    #[test]
    fn masked_oracle_respects_selection() {
        // Row attends only to key 2 → output row == V row 2.
        let (q, k, v) = rand_inputs(1, 5, 4, 5);
        let inp = AttnInputs::new(&q, &k, &v);
        let sel = Selection { rows: vec![vec![2]] };
        let o = masked_attention_oracle(&inp, &sel);
        for p in 0..4 {
            assert!((o.at(0, p) - v.at(2, p)).abs() < 1e-6);
        }
    }

    #[test]
    fn empty_selection_gives_zero_row() {
        let (q, k, v) = rand_inputs(2, 5, 4, 6);
        let inp = AttnInputs::new(&q, &k, &v);
        let sel = Selection { rows: vec![vec![], vec![0, 1]] };
        let o = masked_attention_oracle(&inp, &sel);
        assert!(o.row(0).iter().all(|&x| x == 0.0));
        assert!(o.row(1).iter().any(|&x| x != 0.0));
    }
}
