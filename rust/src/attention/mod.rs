//! Counted attention implementations (the *formal compute* stage).
//!
//! Every implementation computes the same mathematical object —
//! `O = softmax(Q Kᵀ / √d_h) V`, optionally restricted to a per-row key
//! selection — while tallying primitive operations into an
//! [`crate::arith::OpCounter`] and modeling DRAM/SRAM traffic. The bench
//! harness uses the counters to regenerate the paper's complexity figures
//! (Fig. 5, Fig. 11, Fig. 18); the [`crate::sim`] layer converts the same
//! counts into cycles and energy.
//!
//! * [`ref_attn`] — vanilla dense attention (materializes A; the paper's
//!   "vanilla baseline").
//! * [`flash2`] — FlashAttention-2 tiling with online softmax (the paper's
//!   Fig. 5(a) pseudo-code), including the cross-tile max refresh and the
//!   rescaling work SU-FA eliminates.
//! * [`sufa`] — the paper's Sorted-Updating FlashAttention (Sec. IV-C) in
//!   descending (default) and ascending update order, with the
//!   tailored-engine stall model for mispredicted maxima.
//! * [`partials`] — per-partition online-softmax partials
//!   ([`SoftmaxPartial`]) and the fixed-tree cross-shard combine: Star
//!   Attention's phase-2 distributed reduction as a counted kernel
//!   (DESIGN.md §12), property-tested in `tests/prop_softmax_merge.rs`.

pub mod flash2;
pub mod partials;
pub mod ref_attn;
pub mod sufa;

pub use flash2::{flash2_attention, Flash2Params};
pub use partials::{
    merge_partials_tree, softmax_partial_into, softmax_partial_into_with, SoftmaxPartial,
};
pub use ref_attn::{dense_attention, masked_attention_oracle};
pub use sufa::{
    sufa_attention, sufa_attention_rows_into, sufa_attention_rows_into_with, SufaParams,
    SufaScratch, UpdateOrder,
};

use crate::tensor::Mat;

/// Inputs to one attention head: Q [T, d], K [S, d], V [S, d].
/// `scale` is usually 1/√d_h.
#[derive(Clone, Debug)]
pub struct AttnInputs<'a> {
    pub q: &'a Mat,
    pub k: &'a Mat,
    pub v: &'a Mat,
    pub scale: f32,
}

impl<'a> AttnInputs<'a> {
    pub fn new(q: &'a Mat, k: &'a Mat, v: &'a Mat) -> Self {
        assert_eq!(q.cols, k.cols, "Q/K head-dim mismatch");
        assert_eq!(k.rows, v.rows, "K/V length mismatch");
        assert_eq!(k.cols, v.cols, "K/V head-dim mismatch (MHA layout)");
        let scale = 1.0 / (q.cols as f32).sqrt();
        AttnInputs { q, k, v, scale }
    }

    pub fn t(&self) -> usize {
        self.q.rows
    }

    pub fn s(&self) -> usize {
        self.k.rows
    }

    pub fn d(&self) -> usize {
        self.q.cols
    }
}

/// Per-row key selections produced by the top-k stage. `rows[i]` holds the
/// selected key indices for query row `i`; ordering is meaningful (SU-FA
/// consumes them in estimated-score order).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Selection {
    pub rows: Vec<Vec<usize>>,
}

impl Selection {
    /// Full (dense) selection: every key for every row, natural order.
    pub fn full(t: usize, s: usize) -> Selection {
        Selection { rows: vec![(0..s).collect(); t] }
    }

    /// Causal selection: row i attends to keys 0..=i. **Only meaningful
    /// for square attention (T == S)**: with S < T the tail rows would
    /// reference keys that don't exist, and with S > T the late keys are
    /// silently never attended. Consumption sites that assume causality
    /// must pair this with [`Selection::assert_in_range`] (the attention
    /// kernels do so on every selection).
    pub fn causal(t: usize) -> Selection {
        Selection { rows: (0..t).map(|i| (0..=i).collect()).collect() }
    }

    /// Causal selection checked against an explicit context length:
    /// asserts `t == s`, the invariant [`Selection::causal`] silently
    /// assumes.
    pub fn causal_checked(t: usize, s: usize) -> Selection {
        assert_eq!(t, s, "Selection::causal assumes a square T == S attention (got T={t}, S={s})");
        Selection::causal(t)
    }

    /// Panic if any selected index is out of range for a context of `s`
    /// keys. Called by every consumer that indexes K/V with the selection
    /// so a T ≠ S misuse of [`Selection::causal`] fails loudly instead of
    /// reading the wrong rows.
    pub fn assert_in_range(&self, s: usize) {
        assert_rows_in_range(&self.rows, s);
    }

    /// Total number of selected (query, key) pairs.
    pub fn nnz(&self) -> usize {
        self.rows.iter().map(|r| r.len()).sum()
    }

    /// Density relative to a T×S dense attention. Convention: an *empty
    /// problem* (no query rows, or `s == 0`) is vacuously dense and
    /// returns 1.0 — so `Selection::full(t, s).density(s) == 1.0` for
    /// every shape, and density ratios stay well-defined in degenerate
    /// sweeps.
    pub fn density(&self, s: usize) -> f64 {
        if self.rows.is_empty() || s == 0 {
            return 1.0;
        }
        self.nnz() as f64 / (self.rows.len() * s) as f64
    }

    /// The set of keys selected by *any* row — exactly the KV rows the
    /// on-demand generation stage must produce.
    pub fn union_keys(&self, s: usize) -> Vec<usize> {
        let mut needed = vec![false; s];
        for row in &self.rows {
            for &j in row {
                needed[j] = true;
            }
        }
        (0..s).filter(|&j| needed[j]).collect()
    }
}

/// The range check behind [`Selection::assert_in_range`], usable on a
/// bare row slice — the attention kernels' workspace-resident (arena)
/// selection paths run the identical check without building a
/// `Selection`.
pub fn assert_rows_in_range(rows: &[Vec<usize>], s: usize) {
    for (i, row) in rows.iter().enumerate() {
        if let Some(&bad) = row.iter().find(|&&j| j >= s) {
            panic!(
                "selection row {i} references key {bad} but the context has only {s} keys \
                 (Selection::causal used with T != S?)"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_selection_density() {
        let sel = Selection::full(4, 8);
        assert_eq!(sel.nnz(), 32);
        assert_eq!(sel.density(8), 1.0);
        assert_eq!(sel.union_keys(8).len(), 8);
    }

    #[test]
    fn causal_selection() {
        let sel = Selection::causal(4);
        assert_eq!(sel.rows[0], vec![0]);
        assert_eq!(sel.rows[3], vec![0, 1, 2, 3]);
        assert_eq!(sel.nnz(), 10);
    }

    #[test]
    fn union_keys_dedup() {
        let sel = Selection { rows: vec![vec![3, 1], vec![1, 5]] };
        assert_eq!(sel.union_keys(8), vec![1, 3, 5]);
    }

    #[test]
    fn density_empty_problem_is_vacuously_dense() {
        // Convention: consistent with Selection::full always being 1.0.
        assert_eq!(Selection::full(0, 8).density(8), 1.0);
        assert_eq!(Selection::full(4, 0).density(0), 1.0);
        assert_eq!(Selection { rows: vec![] }.density(16), 1.0);
    }

    #[test]
    fn causal_checked_accepts_square() {
        assert_eq!(Selection::causal_checked(5, 5).nnz(), 15);
    }

    #[test]
    #[should_panic(expected = "assumes a square")]
    fn causal_checked_rejects_rectangular() {
        let _ = Selection::causal_checked(8, 4);
    }

    #[test]
    #[should_panic(expected = "references key")]
    fn assert_in_range_catches_causal_misuse() {
        // causal(8) against a 4-key context: rows 4..8 reference keys ≥ 4.
        Selection::causal(8).assert_in_range(4);
    }
}
