//! SU-FA — Sorted-Updating FlashAttention (Sec. IV-C).
//!
//! The top-k stage hands the formal-compute stage a per-row key list
//! *sorted by estimated score*. Visiting tiles in **descending** order means
//! the running max is fixed by the first tile: no cross-tile max
//! comparisons, no `exp(m_old − m_new)` correction factors, no O/l rescales
//! — the redundant work of FA (Fig. 11a) disappears. **Ascending** order
//! also avoids the comparisons (the newest tile always holds the max) but
//! must rescale `l` and the accumulator at every step — the extra
//! multiplications of Fig. 11b that make descend the default.
//!
//! Because the estimate comes from the approximate DLZS predictor, the true
//! max may exceed the first tile's max. The tailored SU-FA engine detects
//! this (the exponent of `exp(x − m)` turns positive) and performs a
//! recovery rescale — a *stall* in hardware terms (Fig. 20 discusses the
//! cost of these stalls on a non-tailored datapath). We reproduce exactly
//! that: [`SufaResult::stalls`] counts recoveries, and the output stays
//! numerically correct regardless of prediction quality.

use super::{AttnInputs, Selection};
use crate::arith::{OpCounter, OpKind};
use crate::tensor::Mat;
use crate::util::ceil_div;

/// Update order for the sorted tiles (Fig. 11b).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateOrder {
    /// Max-first: running max never increases (paper default).
    Descend,
    /// Min-first: max strictly tracks the newest tile; needs per-step
    /// rescaling of `l` and the accumulator.
    Ascend,
}

/// SU-FA execution parameters.
#[derive(Clone, Copy, Debug)]
pub struct SufaParams {
    /// Tile size B_c over the selected keys.
    pub bc: usize,
    pub order: UpdateOrder,
}

impl Default for SufaParams {
    fn default() -> Self {
        SufaParams { bc: 16, order: UpdateOrder::Descend }
    }
}

/// Result of an SU-FA pass.
#[derive(Clone, Debug)]
pub struct SufaResult {
    pub out: Mat,
    /// Max-misprediction recoveries (hardware stalls).
    pub stalls: u64,
}

/// Run SU-FA over the per-row selections. `sel.rows[i]` must be ordered by
/// estimated score (descending). For [`UpdateOrder::Ascend`] the list is
/// consumed back-to-front. On-demand KV traffic: only the union of selected
/// keys is charged.
pub fn sufa_attention(
    inp: &AttnInputs,
    sel: &Selection,
    p: &SufaParams,
    c: &mut OpCounter,
) -> SufaResult {
    let (t, s, d) = (inp.t(), inp.s(), inp.d());
    assert_eq!(sel.rows.len(), t);
    // Fail loudly on selections built for a different context length
    // (e.g. Selection::causal with T != S) instead of reading wrong rows.
    sel.assert_in_range(s);
    let f = 4u64;

    // Traffic: Q once, O once, and only the KV rows some query selected
    // (produced on demand by the PE array — see sim::units::PeArray).
    let kv_rows = sel.union_keys(s).len();
    c.dram(f * (2 * t * d) as u64);
    c.dram(f * (2 * kv_rows * d) as u64);

    let mut out = Mat::zeros(t, d);
    let mut stalls = 0u64;

    for i in 0..t {
        let keys = &sel.rows[i];
        if keys.is_empty() {
            continue;
        }
        let order: Vec<usize> = match p.order {
            UpdateOrder::Descend => keys.clone(),
            UpdateOrder::Ascend => keys.iter().rev().copied().collect(),
        };
        let ntiles = ceil_div(order.len(), p.bc);
        c.sram(f * ((order.len() * d) as u64)); // staged KV tiles

        let mut m = f32::NEG_INFINITY;
        let mut l = 0.0f32;
        let mut acc = vec![0.0f32; d];

        for tile in 0..ntiles {
            let lo = tile * p.bc;
            let hi = (lo + p.bc).min(order.len());
            let width = hi - lo;

            // Scores for this tile.
            let mut scores = vec![0.0f32; width];
            for (w, &j) in order[lo..hi].iter().enumerate() {
                let mut dot = 0.0f32;
                for pth in 0..d {
                    dot += inp.q.at(i, pth) * inp.k.at(j, pth);
                }
                scores[w] = dot * inp.scale;
            }
            c.tally(OpKind::Mul, (width * d + width) as u64);
            c.tally(OpKind::Add, (width * (d - 1)) as u64);

            match p.order {
                UpdateOrder::Descend => {
                    if tile == 0 {
                        // The ONLY max reduction of the whole row.
                        m = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                        c.tally(OpKind::Cmp, (width - 1) as u64);
                    }
                    // Misprediction recovery: a score above m would overflow
                    // exp — detected for free by the exponent sign, repaired
                    // with one FA-style rescale (a stall).
                    let tile_max = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                    if tile_max > m {
                        stalls += 1;
                        let corr = (m - tile_max).exp();
                        c.tally(OpKind::Exp, 1);
                        c.tally(OpKind::Mul, (d + 1) as u64);
                        l *= corr;
                        for x in acc.iter_mut() {
                            *x *= corr;
                        }
                        m = tile_max;
                    }
                }
                UpdateOrder::Ascend => {
                    // Sorted guarantee: this tile holds the new max — no
                    // comparisons, but l and the accumulator must rescale
                    // (the extra multiplications of Fig. 11b).
                    let tile_max = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                    c.tally(OpKind::Cmp, (width - 1) as u64); // in-tile only
                    let m_new = if tile_max > m { tile_max } else { m };
                    if tile > 0 {
                        let corr = (m - m_new).exp();
                        c.tally(OpKind::Add, 1);
                        c.tally(OpKind::Exp, 1);
                        c.tally(OpKind::Mul, (d + 1) as u64);
                        l *= corr;
                        for x in acc.iter_mut() {
                            *x *= corr;
                        }
                    }
                    m = m_new;
                }
            }

            // P = exp(S − m); accumulate l and O.
            c.tally(OpKind::Add, width as u64);
            c.tally(OpKind::Exp, width as u64);
            c.tally(OpKind::Add, (width - 1) as u64);
            for (w, &j) in order[lo..hi].iter().enumerate() {
                let prob = (scores[w] - m).exp();
                l += prob;
                for pth in 0..d {
                    acc[pth] += prob * inp.v.at(j, pth);
                }
            }
            c.tally(OpKind::Add, width as u64); // l accumulation
            c.tally(OpKind::Mul, (width * d) as u64);
            c.tally(OpKind::Add, (width * d) as u64);
        }

        c.tally(OpKind::Div, 1);
        c.tally(OpKind::Mul, d as u64);
        let inv = 1.0 / l;
        for pth in 0..d {
            *out.at_mut(i, pth) = acc[pth] * inv;
        }
    }

    SufaResult { out, stalls }
}

/// Sort each selection row by the *true* attention scores, descending —
/// the perfect-prediction oracle order used in tests and upper-bound
/// studies.
pub fn sort_selection_by_true_scores(inp: &AttnInputs, sel: &Selection) -> Selection {
    let d = inp.d();
    let rows = sel
        .rows
        .iter()
        .enumerate()
        .map(|(i, keys)| {
            let mut scored: Vec<(f32, usize)> = keys
                .iter()
                .map(|&j| {
                    let mut dot = 0.0f32;
                    for p in 0..d {
                        dot += inp.q.at(i, p) * inp.k.at(j, p);
                    }
                    (dot * inp.scale, j)
                })
                .collect();
            scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
            scored.into_iter().map(|(_, j)| j).collect()
        })
        .collect();
    Selection { rows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::ref_attn::{dense_attention, masked_attention_oracle};
    use crate::util::Rng;

    fn inputs(t: usize, s: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        (
            Mat::randn(t, d, 1.0, &mut rng),
            Mat::randn(s, d, 1.0, &mut rng),
            Mat::randn(s, d, 1.0, &mut rng),
        )
    }

    #[test]
    fn full_selection_sorted_matches_dense() {
        let (q, k, v) = inputs(6, 40, 8, 1);
        let inp = AttnInputs::new(&q, &k, &v);
        let sel = sort_selection_by_true_scores(&inp, &Selection::full(6, 40));
        let mut c = OpCounter::new();
        let r = sufa_attention(&inp, &sel, &SufaParams::default(), &mut c);
        let mut dc = OpCounter::new();
        let dense = dense_attention(&inp, usize::MAX, &mut dc);
        assert!(r.out.max_abs_diff(&dense) < 1e-4);
        assert_eq!(r.stalls, 0, "perfectly sorted input must not stall");
    }

    #[test]
    fn ascend_matches_descend_numerically() {
        let (q, k, v) = inputs(5, 32, 8, 2);
        let inp = AttnInputs::new(&q, &k, &v);
        let sel = sort_selection_by_true_scores(&inp, &Selection::full(5, 32));
        let mut c1 = OpCounter::new();
        let mut c2 = OpCounter::new();
        let d = sufa_attention(&inp, &sel, &SufaParams { bc: 8, order: UpdateOrder::Descend }, &mut c1);
        let a = sufa_attention(&inp, &sel, &SufaParams { bc: 8, order: UpdateOrder::Ascend }, &mut c2);
        assert!(d.out.max_abs_diff(&a.out) < 1e-4);
    }

    #[test]
    fn ascend_costs_more_multiplications() {
        // Fig. 11(b): ascend pays an extra multiplication per update step.
        let (q, k, v) = inputs(8, 64, 16, 3);
        let inp = AttnInputs::new(&q, &k, &v);
        let sel = sort_selection_by_true_scores(&inp, &Selection::full(8, 64));
        let mut cd = OpCounter::new();
        let mut ca = OpCounter::new();
        sufa_attention(&inp, &sel, &SufaParams { bc: 8, order: UpdateOrder::Descend }, &mut cd);
        sufa_attention(&inp, &sel, &SufaParams { bc: 8, order: UpdateOrder::Ascend }, &mut ca);
        assert!(ca.mul > cd.mul);
        assert!(ca.exp > cd.exp);
        // Descend does exactly one max reduction per row; ascend does one
        // per tile (in-tile only) — both beat FA2's cross-tile refreshes.
        assert!(cd.cmp < ca.cmp);
    }

    #[test]
    fn descend_eliminates_fa2_overhead() {
        let (q, k, v) = inputs(8, 128, 16, 4);
        let inp = AttnInputs::new(&q, &k, &v);
        let sel = sort_selection_by_true_scores(&inp, &Selection::full(8, 128));
        let mut cs = OpCounter::new();
        sufa_attention(&inp, &sel, &SufaParams { bc: 16, order: UpdateOrder::Descend }, &mut cs);
        let mut cf = OpCounter::new();
        crate::attention::flash2::flash2_attention(
            &inp,
            &crate::attention::Flash2Params { bc: 16, ..Default::default() },
            &mut cf,
        );
        // Same matmul work, strictly fewer exp and cmp.
        assert!(cs.exp < cf.exp, "sufa exp {} !< fa2 exp {}", cs.exp, cf.exp);
        assert!(cs.cmp < cf.cmp);
        // exp savings = T × (Tc − 1) corrections.
        assert_eq!(cf.exp - cs.exp, 8 * (128 / 16 - 1));
    }

    #[test]
    fn topk_selection_matches_masked_oracle() {
        let (q, k, v) = inputs(6, 50, 8, 5);
        let inp = AttnInputs::new(&q, &k, &v);
        // Keep top-10 true keys per row.
        let full = sort_selection_by_true_scores(&inp, &Selection::full(6, 50));
        let sel = Selection { rows: full.rows.iter().map(|r| r[..10].to_vec()).collect() };
        let mut c = OpCounter::new();
        let r = sufa_attention(&inp, &sel, &SufaParams::default(), &mut c);
        let oracle = masked_attention_oracle(&inp, &sel);
        assert!(r.out.max_abs_diff(&oracle) < 1e-4);
    }

    #[test]
    fn mis_sorted_input_stalls_but_stays_correct() {
        let (q, k, v) = inputs(4, 64, 8, 6);
        let inp = AttnInputs::new(&q, &k, &v);
        // Adversarial: ascending order fed to the Descend path.
        let sorted = sort_selection_by_true_scores(&inp, &Selection::full(4, 64));
        let reversed =
            Selection { rows: sorted.rows.iter().map(|r| r.iter().rev().copied().collect()).collect() };
        let mut c = OpCounter::new();
        let r = sufa_attention(&inp, &reversed, &SufaParams { bc: 8, order: UpdateOrder::Descend }, &mut c);
        let mut dc = OpCounter::new();
        let dense = dense_attention(&inp, usize::MAX, &mut dc);
        assert!(r.stalls > 0, "reversed order must trigger recoveries");
        assert!(r.out.max_abs_diff(&dense) < 1e-4, "recovery must preserve numerics");
    }

    #[test]
    fn on_demand_kv_traffic_scales_with_union() {
        let (q, k, v) = inputs(4, 100, 8, 7);
        let inp = AttnInputs::new(&q, &k, &v);
        let narrow = Selection { rows: vec![vec![0, 1, 2, 3]; 4] };
        let wide = Selection { rows: vec![(0..100).collect(); 4] };
        let mut cn = OpCounter::new();
        let mut cw = OpCounter::new();
        sufa_attention(&inp, &narrow, &SufaParams::default(), &mut cn);
        sufa_attention(&inp, &wide, &SufaParams::default(), &mut cw);
        assert!(cn.dram_bytes < cw.dram_bytes);
        // narrow: 2·T·d + 2·4·d floats.
        assert_eq!(cn.dram_bytes, 4 * (2 * 4 * 8 + 2 * 4 * 8) as u64);
    }

    #[test]
    fn empty_rows_are_skipped() {
        let (q, k, v) = inputs(3, 10, 4, 8);
        let inp = AttnInputs::new(&q, &k, &v);
        let sel = Selection { rows: vec![vec![], vec![1], vec![]] };
        let mut c = OpCounter::new();
        let r = sufa_attention(&inp, &sel, &SufaParams::default(), &mut c);
        assert!(r.out.row(0).iter().all(|&x| x == 0.0));
        assert!(r.out.row(2).iter().all(|&x| x == 0.0));
    }
}
