//! SU-FA — Sorted-Updating FlashAttention (Sec. IV-C).
//!
//! The top-k stage hands the formal-compute stage a per-row key list
//! *sorted by estimated score*. Visiting tiles in **descending** order means
//! the running max is fixed by the first tile: no cross-tile max
//! comparisons, no `exp(m_old − m_new)` correction factors, no O/l rescales
//! — the redundant work of FA (Fig. 11a) disappears. **Ascending** order
//! also avoids the comparisons (the newest tile always holds the max) but
//! must rescale `l` and the accumulator at every step — the extra
//! multiplications of Fig. 11b that make descend the default.
//!
//! Because the estimate comes from the approximate DLZS predictor, the true
//! max may exceed the first tile's max. The tailored SU-FA engine detects
//! this (the exponent of `exp(x − m)` turns positive) and performs a
//! recovery rescale — a *stall* in hardware terms (Fig. 20 discusses the
//! cost of these stalls on a non-tailored datapath). We reproduce exactly
//! that: [`SufaResult::stalls`] counts recoveries, and the output stays
//! numerically correct regardless of prediction quality.

use super::{AttnInputs, Selection};
use crate::arith::lanes::{F32x8, KernelPath, ReductionOrder, LANES};
use crate::arith::{OpCounter, OpKind};
use crate::tensor::Mat;
use crate::util::ceil_div;

/// Update order for the sorted tiles (Fig. 11b).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateOrder {
    /// Max-first: running max never increases (paper default).
    Descend,
    /// Min-first: max strictly tracks the newest tile; needs per-step
    /// rescaling of `l` and the accumulator.
    Ascend,
}

/// SU-FA execution parameters.
#[derive(Clone, Copy, Debug)]
pub struct SufaParams {
    /// Tile size B_c over the selected keys.
    pub bc: usize,
    pub order: UpdateOrder,
    /// How the q·k dot product over `d` may be reduced. `Strict` (the
    /// default) keeps the sequential scalar order, so lane and scalar
    /// kernel paths are bit-identical; `Lanes` splits the dot across 8
    /// lanes (fixed pairwise combine — deterministic, ~1 ulp different,
    /// not bit-comparable with `Strict` history). All other SU-FA
    /// reductions (tile max, `l`, rescales) are order-safe or kept
    /// sequential in both modes. See DESIGN.md §10.
    pub reduction: ReductionOrder,
}

impl Default for SufaParams {
    fn default() -> Self {
        SufaParams { bc: 16, order: UpdateOrder::Descend, reduction: ReductionOrder::Strict }
    }
}

/// Result of an SU-FA pass.
#[derive(Clone, Debug)]
pub struct SufaResult {
    pub out: Mat,
    /// Max-misprediction recoveries (hardware stalls).
    pub stalls: u64,
}

/// Reusable scratch for [`sufa_attention_rows_into`]: the running
/// accumulator, the per-tile score buffer and the union-membership flags
/// for the KV-traffic accounting. One per worker thread (owned by
/// [`crate::pipeline::engine::TileWorkspace`]), reused across rows,
/// tiles and requests.
#[derive(Clone, Debug, Default)]
pub struct SufaScratch {
    /// Running output accumulator, one entry per head dimension.
    acc: Vec<f32>,
    /// Per-tile score buffer (`bc` wide).
    scores: Vec<f32>,
    /// Union-membership flags over the context (KV-traffic accounting).
    needed: Vec<bool>,
}

impl SufaScratch {
    /// Pre-grow every buffer for a head dimension `d`, key-tile width
    /// `bc` and context length `s`, so the next pass allocates nothing.
    pub fn reserve(&mut self, d: usize, bc: usize, s: usize) {
        if self.acc.capacity() < d {
            self.acc.reserve(d - self.acc.len());
        }
        if self.scores.capacity() < bc {
            self.scores.reserve(bc - self.scores.len());
        }
        if self.needed.capacity() < s {
            self.needed.reserve(s - self.needed.len());
        }
    }

    /// Bytes of heap capacity currently held (workspace accounting).
    pub fn capacity_bytes(&self) -> usize {
        self.acc.capacity() * std::mem::size_of::<f32>()
            + self.scores.capacity() * std::mem::size_of::<f32>()
            + self.needed.capacity() * std::mem::size_of::<bool>()
    }
}

/// Sequential (scalar-order) q·k dot — the [`ReductionOrder::Strict`]
/// reduction, identical on both kernel paths. Shared with
/// [`super::partials`] so the partial kernel scores bit-identically.
#[inline]
pub(crate) fn dot_strict(q: &[f32], k: &[f32]) -> f32 {
    let mut dot = 0.0f32;
    for (a, b) in q.iter().zip(k) {
        dot += a * b;
    }
    dot
}

/// Lane-split q·k dot — the [`ReductionOrder::Lanes`] reduction: 8
/// partial sums over `d` combined by the fixed pairwise tree
/// ([`F32x8::hsum`]), sequential remainder appended last. Deterministic,
/// but a different rounding order than [`dot_strict`].
#[inline]
pub(crate) fn dot_lanes(q: &[f32], k: &[f32]) -> f32 {
    let mut acc = F32x8::zero();
    let mut qc = q.chunks_exact(LANES);
    let mut kc = k.chunks_exact(LANES);
    for (a, b) in (&mut qc).zip(&mut kc) {
        acc = acc.add(F32x8::load(a).mul(F32x8::load(b)));
    }
    let mut dot = acc.hsum();
    for (a, b) in qc.remainder().iter().zip(kc.remainder()) {
        dot += a * b;
    }
    dot
}

/// Lane spelling of the elementwise `acc[j] += a · x[j]` accumulator
/// update — separate multiply then add per element, so bit-identical to
/// the scalar loop.
#[inline]
pub(crate) fn axpy_lanes(acc: &mut [f32], a: f32, x: &[f32]) {
    let av = F32x8::splat(a);
    let n = acc.len() - acc.len() % LANES;
    let (ac, at) = acc.split_at_mut(n);
    for (ach, xch) in ac.chunks_exact_mut(LANES).zip(x[..n].chunks_exact(LANES)) {
        F32x8::load(ach).add(av.mul(F32x8::load(xch))).store(ach);
    }
    for (o, &b) in at.iter_mut().zip(&x[n..]) {
        *o += a * b;
    }
}

/// Elementwise `xs[j] *= s`, dispatched on the kernel path (the SU-FA
/// recovery/update rescale — bit-identical either way).
#[inline]
pub(crate) fn rescale(path: KernelPath, xs: &mut [f32], s: f32) {
    match path {
        KernelPath::Scalar => {
            for x in xs {
                *x *= s;
            }
        }
        KernelPath::Lanes => {
            let sv = F32x8::splat(s);
            let n = xs.len() - xs.len() % LANES;
            let (c, t) = xs.split_at_mut(n);
            for ch in c.chunks_exact_mut(LANES) {
                F32x8::load(ch).mul(sv).store(ch);
            }
            for x in t {
                *x *= s;
            }
        }
    }
}

/// Lane-split max over a slice seeded −∞ — `f32::max` is associative and
/// commutative (and NaN-ignoring in the same way on every step), so this
/// equals the scalar `fold(NEG_INFINITY, f32::max)` bit for bit.
#[inline]
pub(crate) fn max_lanes(xs: &[f32]) -> f32 {
    let mut acc = F32x8::splat(f32::NEG_INFINITY);
    let mut c = xs.chunks_exact(LANES);
    for ch in &mut c {
        acc = acc.max(F32x8::load(ch));
    }
    acc.max(F32x8::load_or(c.remainder(), f32::NEG_INFINITY)).hmax(f32::NEG_INFINITY)
}

/// Distinct keys selected by any row (the on-demand KV traffic unit),
/// counted with reusable membership flags.
fn union_key_count(rows: &[Vec<usize>], s: usize, needed: &mut Vec<bool>) -> usize {
    needed.clear();
    needed.resize(s, false);
    for row in rows {
        for &j in row {
            needed[j] = true;
        }
    }
    needed.iter().filter(|&&n| n).count()
}

/// Run SU-FA over the per-row selections. `sel.rows[i]` must be ordered by
/// estimated score (descending). For [`UpdateOrder::Ascend`] the list is
/// consumed back-to-front. On-demand KV traffic: only the union of selected
/// keys is charged.
pub fn sufa_attention(
    inp: &AttnInputs,
    sel: &Selection,
    p: &SufaParams,
    c: &mut OpCounter,
) -> SufaResult {
    let mut scratch = SufaScratch::default();
    let mut out = Mat::zeros(0, 0);
    let stalls = sufa_attention_rows_into(inp, &sel.rows, p, c, &mut scratch, &mut out);
    SufaResult { out, stalls }
}

/// [`sufa_attention`] over a bare selection-row slice, writing into a
/// caller-provided output buffer with reusable [`SufaScratch`] — the
/// tile engine's allocation-free formal stage. This is the only SU-FA
/// kernel (the allocating entry point wraps it), so buffered and fresh
/// results — outputs, stalls and op accounting — are identical by
/// construction. Returns the stall count. Dispatches on the `simd`
/// cargo feature ([`KernelPath::active`]).
pub fn sufa_attention_rows_into(
    inp: &AttnInputs,
    rows: &[Vec<usize>],
    p: &SufaParams,
    c: &mut OpCounter,
    scratch: &mut SufaScratch,
    out: &mut Mat,
) -> u64 {
    sufa_attention_rows_into_with(inp, rows, p, c, scratch, out, KernelPath::active())
}

/// [`sufa_attention_rows_into`] with an explicit kernel path, for
/// benches and parity tests.
///
/// Bit-identity under [`ReductionOrder::Strict`]: the q·k dot stays the
/// sequential [`dot_strict`] on **both** paths (a lane-split f32 sum
/// would reorder roundings), while everything the lane path does
/// vectorize — the tile max ([`max_lanes`], associative/commutative
/// `f32::max`), the `exp`-weighted accumulator update ([`axpy_lanes`]),
/// the recovery rescales ([`rescale`]) and the final `acc · (1/l)` — is
/// either order-free or elementwise with unchanged per-element
/// operations. `l` accumulation stays sequential in every mode. Under
/// [`ReductionOrder::Lanes`] the dot switches to [`dot_lanes`] *on both
/// paths*, so path parity holds per reduction mode; only
/// Strict-vs-Lanes results differ (by reduction order, ~1 ulp). Stall
/// detection compares maxima that are bit-equal across paths, so stall
/// counts and op accounting never diverge.
pub fn sufa_attention_rows_into_with(
    inp: &AttnInputs,
    rows: &[Vec<usize>],
    p: &SufaParams,
    c: &mut OpCounter,
    scratch: &mut SufaScratch,
    out: &mut Mat,
    path: KernelPath,
) -> u64 {
    let (t, s, d) = (inp.t(), inp.s(), inp.d());
    assert_eq!(rows.len(), t);
    // Fail loudly on selections built for a different context length
    // (e.g. Selection::causal with T != S) instead of reading wrong rows.
    super::assert_rows_in_range(rows, s);
    let f = 4u64;

    // Traffic: Q once, O once, and only the KV rows some query selected
    // (produced on demand by the PE array — see sim::units::PeArray).
    let kv_rows = union_key_count(rows, s, &mut scratch.needed);
    c.dram(f * (2 * t * d) as u64);
    c.dram(f * (2 * kv_rows * d) as u64);

    out.reset(t, d);
    let mut stalls = 0u64;
    let tile_max_of = |xs: &[f32]| match path {
        KernelPath::Scalar => xs.iter().copied().fold(f32::NEG_INFINITY, f32::max),
        KernelPath::Lanes => max_lanes(xs),
    };

    for i in 0..t {
        let keys = &rows[i];
        if keys.is_empty() {
            continue;
        }
        // Visit order without materializing it: Descend reads the sorted
        // list as-is, Ascend back-to-front (same floats as the old
        // `keys.clone()` / reversed copy, minus the per-row allocation).
        let nkeys = keys.len();
        let key_at = |idx: usize| match p.order {
            UpdateOrder::Descend => keys[idx],
            UpdateOrder::Ascend => keys[nkeys - 1 - idx],
        };
        let ntiles = ceil_div(nkeys, p.bc);
        c.sram(f * ((nkeys * d) as u64)); // staged KV tiles

        let mut m = f32::NEG_INFINITY;
        let mut l = 0.0f32;
        scratch.acc.clear();
        scratch.acc.resize(d, 0.0);
        let acc = &mut scratch.acc;

        for tile in 0..ntiles {
            let lo = tile * p.bc;
            let hi = (lo + p.bc).min(nkeys);
            let width = hi - lo;

            // Scores for this tile.
            scratch.scores.clear();
            scratch.scores.resize(width, 0.0);
            let scores = &mut scratch.scores;
            for (w, slot) in scores.iter_mut().enumerate() {
                let j = key_at(lo + w);
                let dot = match p.reduction {
                    ReductionOrder::Strict => dot_strict(inp.q.row(i), inp.k.row(j)),
                    ReductionOrder::Lanes => dot_lanes(inp.q.row(i), inp.k.row(j)),
                };
                *slot = dot * inp.scale;
            }
            c.tally(OpKind::Mul, (width * d + width) as u64);
            c.tally(OpKind::Add, (width * (d - 1)) as u64);

            match p.order {
                UpdateOrder::Descend => {
                    if tile == 0 {
                        // The ONLY max reduction of the whole row.
                        m = tile_max_of(scores);
                        c.tally(OpKind::Cmp, (width - 1) as u64);
                    }
                    // Misprediction recovery: a score above m would overflow
                    // exp — detected for free by the exponent sign, repaired
                    // with one FA-style rescale (a stall).
                    let tile_max = tile_max_of(scores);
                    if tile_max > m {
                        stalls += 1;
                        let corr = (m - tile_max).exp();
                        c.tally(OpKind::Exp, 1);
                        c.tally(OpKind::Mul, (d + 1) as u64);
                        l *= corr;
                        rescale(path, acc, corr);
                        m = tile_max;
                    }
                }
                UpdateOrder::Ascend => {
                    // Sorted guarantee: this tile holds the new max — no
                    // comparisons, but l and the accumulator must rescale
                    // (the extra multiplications of Fig. 11b).
                    let tile_max = tile_max_of(scores);
                    c.tally(OpKind::Cmp, (width - 1) as u64); // in-tile only
                    let m_new = if tile_max > m { tile_max } else { m };
                    if tile > 0 {
                        let corr = (m - m_new).exp();
                        c.tally(OpKind::Add, 1);
                        c.tally(OpKind::Exp, 1);
                        c.tally(OpKind::Mul, (d + 1) as u64);
                        l *= corr;
                        rescale(path, acc, corr);
                    }
                    m = m_new;
                }
            }

            // P = exp(S − m); accumulate l and O.
            c.tally(OpKind::Add, width as u64);
            c.tally(OpKind::Exp, width as u64);
            c.tally(OpKind::Add, (width - 1) as u64);
            for (w, &score) in scores.iter().enumerate() {
                let j = key_at(lo + w);
                let prob = (score - m).exp();
                l += prob; // sequential in every mode (tiny, order-bearing)
                match path {
                    KernelPath::Scalar => {
                        for (o, &b) in acc.iter_mut().zip(inp.v.row(j)) {
                            *o += prob * b;
                        }
                    }
                    KernelPath::Lanes => axpy_lanes(acc, prob, inp.v.row(j)),
                }
            }
            c.tally(OpKind::Add, width as u64); // l accumulation
            c.tally(OpKind::Mul, (width * d) as u64);
            c.tally(OpKind::Add, (width * d) as u64);
        }

        c.tally(OpKind::Div, 1);
        c.tally(OpKind::Mul, d as u64);
        let inv = 1.0 / l;
        let orow = out.row_mut(i);
        match path {
            KernelPath::Scalar => {
                for (o, &a) in orow.iter_mut().zip(acc.iter()) {
                    *o = a * inv;
                }
            }
            KernelPath::Lanes => {
                let n = d - d % LANES;
                let iv = F32x8::splat(inv);
                for (oc, ac) in orow[..n].chunks_exact_mut(LANES).zip(acc[..n].chunks_exact(LANES))
                {
                    F32x8::load(ac).mul(iv).store(oc);
                }
                for (o, &a) in orow[n..].iter_mut().zip(&acc[n..]) {
                    *o = a * inv;
                }
            }
        }
    }

    stalls
}

/// Sort each selection row by the *true* attention scores, descending —
/// the perfect-prediction oracle order used in tests and upper-bound
/// studies.
pub fn sort_selection_by_true_scores(inp: &AttnInputs, sel: &Selection) -> Selection {
    let d = inp.d();
    let rows = sel
        .rows
        .iter()
        .enumerate()
        .map(|(i, keys)| {
            let mut scored: Vec<(f32, usize)> = keys
                .iter()
                .map(|&j| {
                    let mut dot = 0.0f32;
                    for p in 0..d {
                        dot += inp.q.at(i, p) * inp.k.at(j, p);
                    }
                    (dot * inp.scale, j)
                })
                .collect();
            scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
            scored.into_iter().map(|(_, j)| j).collect()
        })
        .collect();
    Selection { rows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::ref_attn::{dense_attention, masked_attention_oracle};
    use crate::util::Rng;

    fn inputs(t: usize, s: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        (
            Mat::randn(t, d, 1.0, &mut rng),
            Mat::randn(s, d, 1.0, &mut rng),
            Mat::randn(s, d, 1.0, &mut rng),
        )
    }

    #[test]
    fn full_selection_sorted_matches_dense() {
        let (q, k, v) = inputs(6, 40, 8, 1);
        let inp = AttnInputs::new(&q, &k, &v);
        let sel = sort_selection_by_true_scores(&inp, &Selection::full(6, 40));
        let mut c = OpCounter::new();
        let r = sufa_attention(&inp, &sel, &SufaParams::default(), &mut c);
        let mut dc = OpCounter::new();
        let dense = dense_attention(&inp, usize::MAX, &mut dc);
        assert!(r.out.max_abs_diff(&dense) < 1e-4);
        assert_eq!(r.stalls, 0, "perfectly sorted input must not stall");
    }

    #[test]
    fn ascend_matches_descend_numerically() {
        let (q, k, v) = inputs(5, 32, 8, 2);
        let inp = AttnInputs::new(&q, &k, &v);
        let sel = sort_selection_by_true_scores(&inp, &Selection::full(5, 32));
        let mut c1 = OpCounter::new();
        let mut c2 = OpCounter::new();
        let pd = SufaParams { bc: 8, order: UpdateOrder::Descend, ..Default::default() };
        let pa = SufaParams { bc: 8, order: UpdateOrder::Ascend, ..Default::default() };
        let d = sufa_attention(&inp, &sel, &pd, &mut c1);
        let a = sufa_attention(&inp, &sel, &pa, &mut c2);
        assert!(d.out.max_abs_diff(&a.out) < 1e-4);
    }

    #[test]
    fn ascend_costs_more_multiplications() {
        // Fig. 11(b): ascend pays an extra multiplication per update step.
        let (q, k, v) = inputs(8, 64, 16, 3);
        let inp = AttnInputs::new(&q, &k, &v);
        let sel = sort_selection_by_true_scores(&inp, &Selection::full(8, 64));
        let mut cd = OpCounter::new();
        let mut ca = OpCounter::new();
        let pd = SufaParams { bc: 8, order: UpdateOrder::Descend, ..Default::default() };
        let pa = SufaParams { bc: 8, order: UpdateOrder::Ascend, ..Default::default() };
        sufa_attention(&inp, &sel, &pd, &mut cd);
        sufa_attention(&inp, &sel, &pa, &mut ca);
        assert!(ca.mul > cd.mul);
        assert!(ca.exp > cd.exp);
        // Descend does exactly one max reduction per row; ascend does one
        // per tile (in-tile only) — both beat FA2's cross-tile refreshes.
        assert!(cd.cmp < ca.cmp);
    }

    #[test]
    fn descend_eliminates_fa2_overhead() {
        let (q, k, v) = inputs(8, 128, 16, 4);
        let inp = AttnInputs::new(&q, &k, &v);
        let sel = sort_selection_by_true_scores(&inp, &Selection::full(8, 128));
        let mut cs = OpCounter::new();
        let ps = SufaParams { bc: 16, order: UpdateOrder::Descend, ..Default::default() };
        sufa_attention(&inp, &sel, &ps, &mut cs);
        let mut cf = OpCounter::new();
        crate::attention::flash2::flash2_attention(
            &inp,
            &crate::attention::Flash2Params { bc: 16, ..Default::default() },
            &mut cf,
        );
        // Same matmul work, strictly fewer exp and cmp.
        assert!(cs.exp < cf.exp, "sufa exp {} !< fa2 exp {}", cs.exp, cf.exp);
        assert!(cs.cmp < cf.cmp);
        // exp savings = T × (Tc − 1) corrections.
        assert_eq!(cf.exp - cs.exp, 8 * (128 / 16 - 1));
    }

    #[test]
    fn topk_selection_matches_masked_oracle() {
        let (q, k, v) = inputs(6, 50, 8, 5);
        let inp = AttnInputs::new(&q, &k, &v);
        // Keep top-10 true keys per row.
        let full = sort_selection_by_true_scores(&inp, &Selection::full(6, 50));
        let sel = Selection { rows: full.rows.iter().map(|r| r[..10].to_vec()).collect() };
        let mut c = OpCounter::new();
        let r = sufa_attention(&inp, &sel, &SufaParams::default(), &mut c);
        let oracle = masked_attention_oracle(&inp, &sel);
        assert!(r.out.max_abs_diff(&oracle) < 1e-4);
    }

    #[test]
    fn mis_sorted_input_stalls_but_stays_correct() {
        let (q, k, v) = inputs(4, 64, 8, 6);
        let inp = AttnInputs::new(&q, &k, &v);
        // Adversarial: ascending order fed to the Descend path.
        let sorted = sort_selection_by_true_scores(&inp, &Selection::full(4, 64));
        let reversed =
            Selection { rows: sorted.rows.iter().map(|r| r.iter().rev().copied().collect()).collect() };
        let mut c = OpCounter::new();
        let pd = SufaParams { bc: 8, order: UpdateOrder::Descend, ..Default::default() };
        let r = sufa_attention(&inp, &reversed, &pd, &mut c);
        let mut dc = OpCounter::new();
        let dense = dense_attention(&inp, usize::MAX, &mut dc);
        assert!(r.stalls > 0, "reversed order must trigger recoveries");
        assert!(r.out.max_abs_diff(&dense) < 1e-4, "recovery must preserve numerics");
    }

    #[test]
    fn rows_into_reuses_dirty_buffers_bit_identically() {
        // Workspace contract: SU-FA into a dirty output buffer with
        // dirty scratch equals the fresh run — outputs, stalls and op
        // accounting — in both update orders, stalls included.
        let (q, k, v) = inputs(5, 48, 8, 9);
        let inp = AttnInputs::new(&q, &k, &v);
        let sorted = sort_selection_by_true_scores(&inp, &Selection::full(5, 48));
        let reversed = Selection {
            rows: sorted.rows.iter().map(|r| r.iter().rev().copied().collect()).collect(),
        };
        let mut scratch = SufaScratch::default();
        let mut out = Mat::randn(3, 3, 1.0, &mut Rng::new(2)); // dirty, wrong shape
        for sel in [&sorted, &reversed] {
            for order in [UpdateOrder::Descend, UpdateOrder::Ascend] {
                let p = SufaParams { bc: 8, order, ..Default::default() };
                let mut cw = OpCounter::new();
                let want = sufa_attention(&inp, sel, &p, &mut cw);
                let mut cg = OpCounter::new();
                let stalls =
                    sufa_attention_rows_into(&inp, &sel.rows, &p, &mut cg, &mut scratch, &mut out);
                assert_eq!(out.max_abs_diff(&want.out), 0.0, "{order:?} output drift");
                assert_eq!(stalls, want.stalls, "{order:?} stall drift");
                assert_eq!(cg, cw, "{order:?} op drift");
            }
        }
    }

    #[test]
    fn lanes_path_is_bit_identical_to_scalar_in_strict() {
        // d = 10 exercises remainder lanes in the axpy/rescale/final
        // scale; the reversed selection forces stall recoveries through
        // the lane rescale path. Outputs, stalls and ops must all match.
        let (q, k, v) = inputs(5, 33, 10, 21);
        let inp = AttnInputs::new(&q, &k, &v);
        let sorted = sort_selection_by_true_scores(&inp, &Selection::full(5, 33));
        let reversed = Selection {
            rows: sorted.rows.iter().map(|r| r.iter().rev().copied().collect()).collect(),
        };
        let mut s1 = SufaScratch::default();
        let mut s2 = SufaScratch::default();
        let mut o1 = Mat::zeros(0, 0);
        let mut o2 = Mat::randn(2, 2, 1.0, &mut Rng::new(4)); // dirty
        for sel in [&sorted, &reversed] {
            for order in [UpdateOrder::Descend, UpdateOrder::Ascend] {
                let p = SufaParams { bc: 8, order, ..Default::default() };
                let mut c1 = OpCounter::new();
                let mut c2 = OpCounter::new();
                let st1 = sufa_attention_rows_into_with(
                    &inp,
                    &sel.rows,
                    &p,
                    &mut c1,
                    &mut s1,
                    &mut o1,
                    KernelPath::Scalar,
                );
                let st2 = sufa_attention_rows_into_with(
                    &inp,
                    &sel.rows,
                    &p,
                    &mut c2,
                    &mut s2,
                    &mut o2,
                    KernelPath::Lanes,
                );
                assert_eq!(o1.max_abs_diff(&o2), 0.0, "{order:?} output drift");
                assert_eq!(st1, st2, "{order:?} stall drift");
                assert_eq!(c1, c2, "{order:?} op drift");
            }
        }
    }

    #[test]
    fn lanes_reduction_is_path_deterministic_and_close_to_strict() {
        let (q, k, v) = inputs(4, 24, 12, 22);
        let inp = AttnInputs::new(&q, &k, &v);
        let sel = sort_selection_by_true_scores(&inp, &Selection::full(4, 24));
        let mut c = OpCounter::new();
        let strict = sufa_attention(&inp, &sel, &SufaParams::default(), &mut c);
        // In Lanes reduction mode the reordered dot is the same fixed
        // pairwise tree on both kernel paths — path parity must still be
        // exact; only Strict-vs-Lanes may differ (by rounding only).
        let lanes = SufaParams { reduction: ReductionOrder::Lanes, ..Default::default() };
        let mut s1 = SufaScratch::default();
        let mut o1 = Mat::zeros(0, 0);
        let mut o2 = Mat::zeros(0, 0);
        let mut c1 = OpCounter::new();
        let mut c2 = OpCounter::new();
        sufa_attention_rows_into_with(
            &inp,
            &sel.rows,
            &lanes,
            &mut c1,
            &mut s1,
            &mut o1,
            KernelPath::Scalar,
        );
        sufa_attention_rows_into_with(
            &inp,
            &sel.rows,
            &lanes,
            &mut c2,
            &mut s1,
            &mut o2,
            KernelPath::Lanes,
        );
        assert_eq!(o1.max_abs_diff(&o2), 0.0, "Lanes reduction must be path-deterministic");
        assert!(
            o1.max_abs_diff(&strict.out) < 1e-5,
            "Lanes vs Strict should differ by rounding only"
        );
    }

    #[test]
    fn on_demand_kv_traffic_scales_with_union() {
        let (q, k, v) = inputs(4, 100, 8, 7);
        let inp = AttnInputs::new(&q, &k, &v);
        let narrow = Selection { rows: vec![vec![0, 1, 2, 3]; 4] };
        let wide = Selection { rows: vec![(0..100).collect(); 4] };
        let mut cn = OpCounter::new();
        let mut cw = OpCounter::new();
        sufa_attention(&inp, &narrow, &SufaParams::default(), &mut cn);
        sufa_attention(&inp, &wide, &SufaParams::default(), &mut cw);
        assert!(cn.dram_bytes < cw.dram_bytes);
        // narrow: 2·T·d + 2·4·d floats.
        assert_eq!(cn.dram_bytes, 4 * (2 * 4 * 8 + 2 * 4 * 8) as u64);
    }

    #[test]
    fn empty_rows_are_skipped() {
        let (q, k, v) = inputs(3, 10, 4, 8);
        let inp = AttnInputs::new(&q, &k, &v);
        let sel = Selection { rows: vec![vec![], vec![1], vec![]] };
        let mut c = OpCounter::new();
        let r = sufa_attention(&inp, &sel, &SufaParams::default(), &mut c);
        assert!(r.out.row(0).iter().all(|&x| x == 0.0));
        assert!(r.out.row(2).iter().all(|&x| x == 0.0));
    }
}
