//! FlashAttention-2 with op accounting — the paper's Fig. 5(a) baseline.
//!
//! Tiling over key/value columns with the online-softmax update:
//! per tile `j`: `m_new = max(m, rowmax(S_j))`, `P = exp(S_j − m_new)`,
//! `corr = exp(m − m_new)`, `l = corr·l + Σ P`, `O = corr·O + P V_j`.
//! The cross-tile max refreshes and the `corr` exponentials/rescales are
//! exactly the redundancy SU-FA removes (Fig. 11a).
//!
//! Comparison accounting (see EXPERIMENTS.md §fig5 for the calibration):
//! each tile costs `B_c − 1` in-tile comparisons plus 2 cross-tile ones
//! (max merge + rescale decision), which reproduces the paper's "~0.3 M
//! extra comparisons at S = 2048, B_c = 16". With
//! `count_rescale_as_exp = true` the per-element application of
//! `diag(exp(m−m_new))` to O/l is charged as exponential work — the
//! accounting under which the paper's "8 M more exponentiations" holds;
//! strict accounting (default) charges 1 exp per row per tile.

use super::AttnInputs;
use crate::arith::{OpCounter, OpKind};
use crate::tensor::Mat;
use crate::util::ceil_div;

/// FlashAttention-2 tiling parameters.
#[derive(Clone, Copy, Debug)]
pub struct Flash2Params {
    /// Row-block size B_r (affects K/V re-streaming traffic).
    pub br: usize,
    /// Column-tile size B_c.
    pub bc: usize,
    /// Causal masking (decoder models): tiles fully above the diagonal are
    /// skipped; partial tiles are computed in full (hardware does too).
    pub causal: bool,
    /// Charge the per-element rescale of O and l as exp work (paper's
    /// accounting for Fig. 5b); otherwise charge 1 exp per row per tile.
    pub count_rescale_as_exp: bool,
}

impl Default for Flash2Params {
    fn default() -> Self {
        Flash2Params { br: 64, bc: 16, causal: false, count_rescale_as_exp: false }
    }
}

/// FlashAttention-2 forward for one head. Returns O [T, d].
pub fn flash2_attention(inp: &AttnInputs, p: &Flash2Params, c: &mut OpCounter) -> Mat {
    let (t, s, d) = (inp.t(), inp.s(), inp.d());
    assert!(p.bc >= 1 && p.br >= 1);
    let tc = ceil_div(s, p.bc);
    let tr = ceil_div(t, p.br);
    let f = 4u64;

    // Traffic: Q and O move once; K/V stream once per row block (the
    // FlashAttention IO model with K/V tiles resident only per pass).
    c.dram(f * (t * d) as u64); // Q in
    c.dram(f * (t * d) as u64); // O out
    c.dram(f * (tr * 2 * s * d) as u64); // K+V per row-block pass
    c.sram(f * ((p.br * d + 2 * p.bc * d + p.br * p.bc) * tr * tc) as u64);

    let mut out = Mat::zeros(t, d);
    for i in 0..t {
        let qi = inp.q.row(i);
        let mut m = f32::NEG_INFINITY;
        let mut l = 0.0f32;
        let mut acc = vec![0.0f32; d];
        let mut first = true;
        for tile in 0..tc {
            let j0 = tile * p.bc;
            let j1 = (j0 + p.bc).min(s);
            if p.causal && j0 > i {
                break; // fully-masked tile (and all later ones)
            }
            let width = j1 - j0;

            // S_tile = q_i · K_jᵀ · scale
            let mut scores = vec![0.0f32; width];
            for (w, j) in (j0..j1).enumerate() {
                let kj = inp.k.row(j);
                let mut dot = 0.0f32;
                for pth in 0..d {
                    dot += qi[pth] * kj[pth];
                }
                scores[w] = dot * inp.scale;
                if p.causal && j > i {
                    scores[w] = f32::NEG_INFINITY;
                }
            }
            c.tally(OpKind::Mul, (width * d + width) as u64);
            c.tally(OpKind::Add, (width * (d - 1)) as u64);

            // m_new = max(m, rowmax(S_tile))
            let tile_max = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            c.tally(OpKind::Cmp, (width - 1) as u64); // in-tile reduction
            let m_new = if first {
                tile_max
            } else {
                c.tally(OpKind::Cmp, 2); // cross-tile merge + rescale decision
                m.max(tile_max)
            };

            // P = exp(S − m_new)
            c.tally(OpKind::Add, width as u64);
            c.tally(OpKind::Exp, width as u64);
            let probs: Vec<f32> = scores.iter().map(|&x| (x - m_new).exp()).collect();
            let row_sum: f32 = probs.iter().sum();
            c.tally(OpKind::Add, (width - 1) as u64);

            if first {
                l = row_sum;
                for (w, j) in (j0..j1).enumerate() {
                    let vj = inp.v.row(j);
                    for pth in 0..d {
                        acc[pth] += probs[w] * vj[pth];
                    }
                }
                first = false;
            } else {
                // corr = exp(m − m_new); rescale l and O.
                let corr = (m - m_new).exp();
                c.tally(OpKind::Add, 1);
                if p.count_rescale_as_exp {
                    // Paper-style accounting: applying diag(exp(·)) over the
                    // d-wide accumulator plus l is exponential-unit work.
                    c.tally(OpKind::Exp, (d + 2) as u64);
                } else {
                    c.tally(OpKind::Exp, 1);
                    c.tally(OpKind::Mul, (d + 1) as u64); // O and l rescale
                }
                l = corr * l + row_sum;
                c.tally(OpKind::Add, 1);
                for x in acc.iter_mut() {
                    *x *= corr;
                }
                for (w, j) in (j0..j1).enumerate() {
                    let vj = inp.v.row(j);
                    for pth in 0..d {
                        acc[pth] += probs[w] * vj[pth];
                    }
                }
            }
            c.tally(OpKind::Mul, (width * d) as u64); // P · V_tile
            c.tally(OpKind::Add, (width * d) as u64);
            m = m_new;
        }
        // Final normalization: one reciprocal + d multiplies.
        c.tally(OpKind::Div, 1);
        c.tally(OpKind::Mul, d as u64);
        let inv = 1.0 / l;
        for pth in 0..d {
            *out.at_mut(i, pth) = acc[pth] * inv;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::ref_attn::dense_attention;
    use crate::util::Rng;

    fn inputs(t: usize, s: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        (
            Mat::randn(t, d, 1.0, &mut rng),
            Mat::randn(s, d, 1.0, &mut rng),
            Mat::randn(s, d, 1.0, &mut rng),
        )
    }

    #[test]
    fn matches_dense_attention() {
        let (q, k, v) = inputs(7, 33, 16, 1);
        let inp = AttnInputs::new(&q, &k, &v);
        let mut c1 = OpCounter::new();
        let mut c2 = OpCounter::new();
        let dense = dense_attention(&inp, usize::MAX, &mut c1);
        for bc in [4, 8, 33] {
            let fa = flash2_attention(&inp, &Flash2Params { bc, ..Default::default() }, &mut c2);
            assert!(fa.max_abs_diff(&dense) < 1e-4, "bc={bc}");
        }
    }

    #[test]
    fn causal_matches_masked_oracle() {
        let (q, k, v) = inputs(12, 12, 8, 2);
        let inp = AttnInputs::new(&q, &k, &v);
        let mut c = OpCounter::new();
        let fa = flash2_attention(
            &inp,
            &Flash2Params { bc: 4, causal: true, ..Default::default() },
            &mut c,
        );
        let oracle = crate::attention::ref_attn::masked_attention_oracle(
            &inp,
            &crate::attention::Selection::causal(12),
        );
        assert!(fa.max_abs_diff(&oracle) < 1e-4);
    }

    #[test]
    fn extra_exp_grows_with_tile_count() {
        let (q, k, v) = inputs(8, 256, 16, 3);
        let inp = AttnInputs::new(&q, &k, &v);
        let mut dense_c = OpCounter::new();
        dense_attention(&inp, usize::MAX, &mut dense_c);
        let mut prev_extra = 0u64;
        for bc in [64, 16, 4] {
            let mut c = OpCounter::new();
            flash2_attention(&inp, &Flash2Params { bc, ..Default::default() }, &mut c);
            let extra = c.exp - dense_c.exp;
            assert!(extra > prev_extra, "bc={bc}: {extra} !> {prev_extra}");
            prev_extra = extra;
        }
    }

    #[test]
    fn strict_extra_op_formulas() {
        let (t, s, d, bc) = (4usize, 64usize, 8usize, 8usize);
        let (q, k, v) = inputs(t, s, d, 4);
        let inp = AttnInputs::new(&q, &k, &v);
        let mut dc = OpCounter::new();
        dense_attention(&inp, usize::MAX, &mut dc);
        let mut fc = OpCounter::new();
        flash2_attention(&inp, &Flash2Params { br: 2, bc, ..Default::default() }, &mut fc);
        let tc = s / bc;
        // Corrections: one exp per row per non-first tile.
        assert_eq!(fc.exp - dc.exp, (t * (tc - 1)) as u64);
        // Cross-tile comparisons: 2 per row per non-first tile, minus the
        // dense max chain length discrepancy (dense: s-1; fa in-tile: s-tc).
        let fa_cmp = (t * (s - tc + 2 * (tc - 1))) as u64;
        assert_eq!(fc.cmp, fa_cmp);
    }

    #[test]
    fn paper_scale_smoke_s2048() {
        // S = T = 2048, B_c = 16 → extra comparisons ≈ 0.26 M (paper: 0.3 M)
        // — computed from the formulas rather than running a 2048² attention.
        let (t, s, bc) = (2048u64, 2048u64, 16u64);
        let tc = s / bc;
        let extra_cmp = t * (tc - 1);
        assert!((2.0e5..4.0e5).contains(&(extra_cmp as f64)), "extra_cmp={extra_cmp}");
        // Paper-style exp accounting with causal d=64: ≈ 8 M extra exps.
        let d = 64u64;
        let extra_exp_paper = t * (tc - 1) * (d + 2) / 2;
        assert!((6.0e6..1.2e7).contains(&(extra_exp_paper as f64)), "{extra_exp_paper}");
    }

    #[test]
    fn kv_traffic_scales_with_row_blocks() {
        let (q, k, v) = inputs(32, 64, 8, 5);
        let inp = AttnInputs::new(&q, &k, &v);
        let mut c8 = OpCounter::new();
        flash2_attention(&inp, &Flash2Params { br: 8, bc: 16, ..Default::default() }, &mut c8);
        let mut c32 = OpCounter::new();
        flash2_attention(&inp, &Flash2Params { br: 32, bc: 16, ..Default::default() }, &mut c32);
        assert!(c8.dram_bytes > c32.dram_bytes);
    }
}
