//! Per-partition online-softmax **partials** and the cross-shard combine
//! — Star Attention's phase-2 "global query against distributed KV"
//! reduction (PAPERS.md, arxiv 2411.17116) as a first-class counted
//! kernel.
//!
//! A [`SoftmaxPartial`] carries the running max `m`, the softmax
//! denominator `l` and the un-normalized accumulator `acc` of one query
//! row restricted to one key partition. Partials over disjoint
//! partitions combine exactly ([`SoftmaxPartial::combine`]): rescale
//! both sides to the joint max, sum denominators and accumulators. The
//! combine is commutative (IEEE f32 `+` and `max` are) but **not
//! associative**, so distributed reductions fix the combine *tree*:
//! [`merge_partials_tree`] folds a partition-indexed slice with a
//! left-balanced pairwise tree, making the result deterministic at
//! every partition count and independent of arrival order.
//!
//! The per-partition accumulation loop ([`softmax_partial_into`]) is
//! spelled to match [`super::sufa`]'s **Ascend** update arm operation
//! for operation — same tile max, same `exp(m_old − m_new)` rescale,
//! same sequential `l` accumulation, same lane/scalar spellings — so a
//! single-partition partial finalizes bit-identically to the unsharded
//! SU-FA kernel fed the same visit order (pinned in
//! `tests/prop_softmax_merge.rs`).
//!
//! **Where it is used.** The sharded decode path keeps bit-identity
//! with single-core decode by running the *unpartitioned* formal kernel
//! at the query's home worker (DESIGN.md §12), so this kernel is not on
//! that path. It is the documented *tolerance-mode* distributed formal:
//! `star bench decode --sharded` computes per-page partials and merges
//! them through the fixed tree, reporting the measured deviation
//! against the exact kernel in `BENCH_decode.json`.

use super::sufa::{axpy_lanes, dot_lanes, dot_strict, max_lanes, rescale};
use crate::arith::lanes::{F32x8, KernelPath, ReductionOrder, LANES};
use crate::arith::{OpCounter, OpKind};
use crate::tensor::Mat;
use crate::util::ceil_div;

/// Online-softmax state of one query row over one key partition:
/// running max, denominator, and the `d`-wide un-normalized output
/// accumulator. An *empty* partial (`m == −∞`, `l == 0`) is the combine
/// identity.
#[derive(Clone, Debug, PartialEq)]
pub struct SoftmaxPartial {
    m: f32,
    l: f32,
    acc: Vec<f32>,
}

impl SoftmaxPartial {
    /// The empty partial for head dimension `d` — the identity of
    /// [`SoftmaxPartial::combine`].
    pub fn empty(d: usize) -> SoftmaxPartial {
        SoftmaxPartial { m: f32::NEG_INFINITY, l: 0.0, acc: vec![0.0; d] }
    }

    /// Running max of the scores seen so far (−∞ when empty).
    pub fn m(&self) -> f32 {
        self.m
    }

    /// Softmax denominator accumulated at the current max.
    pub fn l(&self) -> f32 {
        self.l
    }

    /// Head dimension of the accumulator.
    pub fn d(&self) -> usize {
        self.acc.len()
    }

    /// Reset to the empty partial for head dimension `d`, reusing the
    /// accumulator's capacity (no allocation once warm).
    pub fn reset(&mut self, d: usize) {
        self.m = f32::NEG_INFINITY;
        self.l = 0.0;
        self.acc.clear();
        self.acc.resize(d, 0.0);
    }

    /// Pre-grow the accumulator for head dimension `d`.
    pub fn reserve(&mut self, d: usize) {
        if self.acc.capacity() < d {
            self.acc.reserve(d - self.acc.len());
        }
    }

    /// Bytes of heap capacity currently held (workspace accounting).
    pub fn capacity_bytes(&self) -> usize {
        self.acc.capacity() * std::mem::size_of::<f32>()
    }

    /// Fold `other` into `self`: the exact online-softmax combine of two
    /// partials over **disjoint** key sets.
    ///
    /// `M = max(mₐ, m_b)`, `cₓ = exp(mₓ − M)`, `l = cₐ·lₐ + c_b·l_b`,
    /// `acc[j] = cₐ·accₐ[j] + c_b·acc_b[j]`. Empty sides (`m == −∞`) are
    /// identity absorbed without evaluating `exp(−∞ − −∞)`, so
    /// degenerate shards (empty selections, all-−∞ scores) are safe.
    /// Commutative, **not** associative — distributed merges must fix
    /// the tree ([`merge_partials_tree`]).
    pub fn combine(&mut self, other: &SoftmaxPartial, c: &mut OpCounter) {
        assert_eq!(self.acc.len(), other.acc.len(), "partial head-dim mismatch");
        c.tally(OpKind::Cmp, 1);
        if other.m == f32::NEG_INFINITY {
            return;
        }
        if self.m == f32::NEG_INFINITY {
            self.m = other.m;
            self.l = other.l;
            self.acc.copy_from_slice(&other.acc);
            return;
        }
        let d = self.acc.len();
        let big = if other.m > self.m { other.m } else { self.m };
        let ca = (self.m - big).exp();
        let cb = (other.m - big).exp();
        c.tally(OpKind::Add, 2);
        c.tally(OpKind::Exp, 2);
        // l and acc: two multiplies + one add per element.
        c.tally(OpKind::Mul, (2 * (d + 1)) as u64);
        c.tally(OpKind::Add, (d + 1) as u64);
        self.l = ca * self.l + cb * other.l;
        for (a, &b) in self.acc.iter_mut().zip(&other.acc) {
            *a = ca * *a + cb * b;
        }
        self.m = big;
    }

    /// Normalize into `out` (`d`-wide): `out = acc · (1/l)`, or zeros
    /// when the partial is empty (`l == 0`) — the same convention as the
    /// SU-FA kernel's skipped empty rows. Dispatches on the `simd`
    /// feature ([`KernelPath::active`]).
    pub fn finalize_into(&self, c: &mut OpCounter, out: &mut [f32]) {
        self.finalize_into_with(c, out, KernelPath::active());
    }

    /// [`SoftmaxPartial::finalize_into`] with an explicit kernel path —
    /// the scalar and lane spellings are the SU-FA kernel's final-scale
    /// loops, bit-identical to each other and to it.
    pub fn finalize_into_with(&self, c: &mut OpCounter, out: &mut [f32], path: KernelPath) {
        let d = self.acc.len();
        assert_eq!(out.len(), d, "output head-dim mismatch");
        if self.l == 0.0 {
            out.fill(0.0);
            return;
        }
        c.tally(OpKind::Div, 1);
        c.tally(OpKind::Mul, d as u64);
        let inv = 1.0 / self.l;
        match path {
            KernelPath::Scalar => {
                for (o, &a) in out.iter_mut().zip(self.acc.iter()) {
                    *o = a * inv;
                }
            }
            KernelPath::Lanes => {
                let n = d - d % LANES;
                let iv = F32x8::splat(inv);
                for (oc, ac) in
                    out[..n].chunks_exact_mut(LANES).zip(self.acc[..n].chunks_exact(LANES))
                {
                    F32x8::load(ac).mul(iv).store(oc);
                }
                for (o, &a) in out[n..].iter_mut().zip(&self.acc[n..]) {
                    *o = a * inv;
                }
            }
        }
    }
}

/// Accumulate the keys of one partition into `out` (which is reset
/// first) for query row `q`, visiting `keys` front-to-back in tiles of
/// `bc`. Dispatches on the `simd` feature ([`KernelPath::active`]).
#[allow(clippy::too_many_arguments)]
pub fn softmax_partial_into(
    q: &[f32],
    k: &Mat,
    v: &Mat,
    keys: &[usize],
    scale: f32,
    bc: usize,
    reduction: ReductionOrder,
    c: &mut OpCounter,
    out: &mut SoftmaxPartial,
) {
    softmax_partial_into_with(q, k, v, keys, scale, bc, reduction, c, out, KernelPath::active());
}

/// [`softmax_partial_into`] with an explicit kernel path.
///
/// The loop body is the SU-FA **Ascend** update arm verbatim — per-tile
/// score + tile max, `exp(m_old − m_new)` rescale of `l` and the
/// accumulator after the first tile, sequential `l` accumulation, the
/// same lane/scalar accumulator spellings and the same op tallies — so
/// a single whole-row partition finalizes bit-identically to
/// [`super::sufa::sufa_attention_rows_into_with`] under
/// [`super::UpdateOrder::Ascend`] given the same visit order (Ascend
/// consumes its sorted list back-to-front; pass the reversed list
/// here). SRAM staging is charged per partition (`4·|keys|·d`), so
/// charges over a partition of a row sum exactly to the whole-row
/// charge; the pass-level DRAM charges stay with the caller.
#[allow(clippy::too_many_arguments)]
pub fn softmax_partial_into_with(
    q: &[f32],
    k: &Mat,
    v: &Mat,
    keys: &[usize],
    scale: f32,
    bc: usize,
    reduction: ReductionOrder,
    c: &mut OpCounter,
    out: &mut SoftmaxPartial,
    path: KernelPath,
) {
    let d = q.len();
    assert_eq!(k.cols, d, "Q/K head-dim mismatch");
    assert_eq!(v.cols, d, "K/V head-dim mismatch");
    out.reset(d);
    let nkeys = keys.len();
    if nkeys == 0 {
        return;
    }
    let bc = bc.max(1);
    let ntiles = ceil_div(nkeys, bc);
    c.sram(4 * (nkeys * d) as u64); // staged KV tiles

    let tile_max_of = |xs: &[f32]| match path {
        KernelPath::Scalar => xs.iter().copied().fold(f32::NEG_INFINITY, f32::max),
        KernelPath::Lanes => max_lanes(xs),
    };

    let mut scores = [0.0f32; 64];
    let mut heap_scores: Vec<f32>;
    let scores: &mut [f32] = if bc <= scores.len() {
        &mut scores
    } else {
        heap_scores = vec![0.0; bc];
        &mut heap_scores
    };

    for tile in 0..ntiles {
        let lo = tile * bc;
        let hi = (lo + bc).min(nkeys);
        let width = hi - lo;
        let scores = &mut scores[..width];
        for (w, slot) in scores.iter_mut().enumerate() {
            let j = keys[lo + w];
            let dot = match reduction {
                ReductionOrder::Strict => dot_strict(q, k.row(j)),
                ReductionOrder::Lanes => dot_lanes(q, k.row(j)),
            };
            *slot = dot * scale;
        }
        c.tally(OpKind::Mul, (width * d + width) as u64);
        c.tally(OpKind::Add, (width * (d - 1)) as u64);

        let tile_max = tile_max_of(scores);
        c.tally(OpKind::Cmp, (width - 1) as u64);
        let m_new = if tile_max > out.m { tile_max } else { out.m };
        if tile > 0 {
            let corr = (out.m - m_new).exp();
            c.tally(OpKind::Add, 1);
            c.tally(OpKind::Exp, 1);
            c.tally(OpKind::Mul, (d + 1) as u64);
            out.l *= corr;
            rescale(path, &mut out.acc, corr);
        }
        out.m = m_new;

        c.tally(OpKind::Add, width as u64);
        c.tally(OpKind::Exp, width as u64);
        c.tally(OpKind::Add, (width - 1) as u64);
        for (w, &score) in scores.iter().enumerate() {
            let j = keys[lo + w];
            let prob = (score - out.m).exp();
            out.l += prob; // sequential in every mode (order-bearing)
            match path {
                KernelPath::Scalar => {
                    for (o, &b) in out.acc.iter_mut().zip(v.row(j)) {
                        *o += prob * b;
                    }
                }
                KernelPath::Lanes => axpy_lanes(&mut out.acc, prob, v.row(j)),
            }
        }
        c.tally(OpKind::Add, width as u64); // l accumulation
        c.tally(OpKind::Mul, (width * d) as u64);
        c.tally(OpKind::Add, (width * d) as u64);
    }
}

/// Fold a partition-indexed slice of partials with a **fixed
/// left-balanced pairwise tree** (stride doubling: 0⊕1, 2⊕3, … then
/// 0⊕2, 4⊕6, …), leaving the result in `parts[0]`. The tree shape
/// depends only on `parts.len()`, so for partials presented in
/// partition-index order the result is deterministic at every partition
/// count and independent of which shard finished first. Panics on an
/// empty slice — fold the identity ([`SoftmaxPartial::empty`]) in
/// explicitly if a zero-partition merge can occur.
pub fn merge_partials_tree<'a>(
    parts: &'a mut [SoftmaxPartial],
    c: &mut OpCounter,
) -> &'a SoftmaxPartial {
    assert!(!parts.is_empty(), "merge_partials_tree over zero partials");
    let n = parts.len();
    let mut stride = 1;
    while stride < n {
        let mut i = 0;
        while i + stride < n {
            let (left, right) = parts.split_at_mut(i + stride);
            left[i].combine(&right[0], c);
            i += 2 * stride;
        }
        stride *= 2;
    }
    &parts[0]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn kv(s: usize, d: usize, seed: u64) -> (Mat, Mat, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let k = Mat::randn(s, d, 1.0, &mut rng);
        let v = Mat::randn(s, d, 1.0, &mut rng);
        let q: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        (k, v, q)
    }

    #[test]
    fn empty_partial_is_combine_identity() {
        let (k, v, q) = kv(12, 8, 1);
        let keys: Vec<usize> = (0..12).collect();
        let mut c = OpCounter::new();
        let mut p = SoftmaxPartial::empty(8);
        softmax_partial_into(&q, &k, &v, &keys, 0.3, 4, ReductionOrder::Strict, &mut c, &mut p);
        let mut left = p.clone();
        left.combine(&SoftmaxPartial::empty(8), &mut c);
        assert_eq!(left, p, "identity on the right");
        let mut right = SoftmaxPartial::empty(8);
        right.combine(&p, &mut c);
        assert_eq!(right, p, "identity on the left");
    }

    #[test]
    fn split_partition_combines_to_whole() {
        // One row split at every cut point: combine(left, right) must
        // finalize close to the unsplit partial (exact agreement with
        // the monolithic kernel is pinned in tests/prop_softmax_merge).
        let (k, v, q) = kv(24, 8, 2);
        let keys: Vec<usize> = (0..24).collect();
        let mut c = OpCounter::new();
        let mut whole = SoftmaxPartial::empty(8);
        softmax_partial_into(&q, &k, &v, &keys, 0.2, 8, ReductionOrder::Strict, &mut c, &mut whole);
        let mut want = vec![0.0f32; 8];
        whole.finalize_into(&mut c, &mut want);
        for cut in [1usize, 7, 12, 23] {
            let mut a = SoftmaxPartial::empty(8);
            let mut b = SoftmaxPartial::empty(8);
            softmax_partial_into(
                &q, &k, &v, &keys[..cut], 0.2, 8, ReductionOrder::Strict, &mut c, &mut a,
            );
            softmax_partial_into(
                &q, &k, &v, &keys[cut..], 0.2, 8, ReductionOrder::Strict, &mut c, &mut b,
            );
            a.combine(&b, &mut c);
            let mut got = vec![0.0f32; 8];
            a.finalize_into(&mut c, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-5, "cut={cut}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn tree_merge_is_deterministic_in_arrival_order() {
        let (k, v, q) = kv(40, 8, 3);
        let mut c = OpCounter::new();
        let parts: Vec<SoftmaxPartial> = (0..5)
            .map(|j| {
                let keys: Vec<usize> = (j * 8..(j + 1) * 8).collect();
                let mut p = SoftmaxPartial::empty(8);
                softmax_partial_into(
                    &q, &k, &v, &keys, 0.25, 4, ReductionOrder::Strict, &mut c, &mut p,
                );
                p
            })
            .collect();
        // However the shards finish, the merger sorts by partition
        // index first — the tree sees the same sequence.
        let mut a = parts.clone();
        let mut b = parts.clone();
        let ra = merge_partials_tree(&mut a, &mut c).clone();
        let rb = merge_partials_tree(&mut b, &mut c).clone();
        assert_eq!(ra, rb);
        let mut out = vec![0.0f32; 8];
        ra.finalize_into(&mut c, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn finalize_of_empty_partial_is_zeros() {
        let p = SoftmaxPartial::empty(6);
        let mut c = OpCounter::new();
        let mut out = vec![7.0f32; 6];
        p.finalize_into(&mut c, &mut out);
        assert!(out.iter().all(|&x| x == 0.0));
    }
}
