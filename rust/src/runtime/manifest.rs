//! Artifact manifest: `artifacts/manifest.json`, written by
//! `python/compile/aot.py` alongside the HLO-text files.

use crate::util::json::Json;
use crate::Result;
use std::path::{Path, PathBuf};

/// One AOT-compiled entry point.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    /// Logical name, e.g. `"sparse_attention"` or `"transformer_block"`.
    pub name: String,
    /// HLO-text file name relative to the artifact directory.
    pub file: String,
    /// Input shapes in call order (row-major f32).
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes (the lowering returns a tuple in this order).
    pub outputs: Vec<Vec<usize>>,
}

impl ArtifactEntry {
    pub fn to_json(&self) -> Json {
        let shapes = |ss: &[Vec<usize>]| {
            Json::Arr(
                ss.iter()
                    .map(|s| Json::Arr(s.iter().map(|&d| Json::num(d as f64)).collect()))
                    .collect(),
            )
        };
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("file", Json::str(&self.file)),
            ("inputs", shapes(&self.inputs)),
            ("outputs", shapes(&self.outputs)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<ArtifactEntry> {
        let shapes = |key: &str| -> Option<Vec<Vec<usize>>> {
            j.get(key)?
                .as_arr()?
                .iter()
                .map(|s| s.as_arr().map(|dims| dims.iter().filter_map(|d| d.as_usize()).collect()))
                .collect()
        };
        Some(ArtifactEntry {
            name: j.get("name")?.as_str()?.to_string(),
            file: j.get("file")?.as_str()?.to_string(),
            inputs: shapes("inputs")?,
            outputs: shapes("outputs")?,
        })
    }
}

/// The manifest: all entry points of one artifact directory.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Manifest {
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "artifacts",
            Json::Arr(self.entries.iter().map(|e| e.to_json()).collect()),
        )])
    }

    pub fn from_json(j: &Json) -> Option<Manifest> {
        let entries = j
            .get("artifacts")?
            .as_arr()?
            .iter()
            .map(ArtifactEntry::from_json)
            .collect::<Option<Vec<_>>>()?;
        Some(Manifest { entries })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("parse manifest: {e}"))?;
        Manifest::from_json(&j).ok_or_else(|| anyhow::anyhow!("malformed manifest.json"))
    }

    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join("manifest.json"), self.to_json().pretty())?;
        Ok(())
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    pub fn hlo_path(&self, dir: &Path, name: &str) -> Option<PathBuf> {
        self.get(name).map(|e| dir.join(&e.file))
    }
}

/// Default artifact directory: `$STAR_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("STAR_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        Manifest {
            entries: vec![
                ArtifactEntry {
                    name: "sparse_attention".into(),
                    file: "sparse_attention.hlo.txt".into(),
                    inputs: vec![vec![8, 64], vec![128, 64], vec![128, 64]],
                    outputs: vec![vec![8, 64]],
                },
                ArtifactEntry {
                    name: "block".into(),
                    file: "block.hlo.txt".into(),
                    inputs: vec![vec![8, 128]],
                    outputs: vec![vec![8, 128], vec![8]],
                },
            ],
        }
    }

    #[test]
    fn json_roundtrip() {
        let m = sample();
        let j = m.to_json();
        assert_eq!(Manifest::from_json(&j).unwrap(), m);
        let reparsed = Json::parse(&j.pretty()).unwrap();
        assert_eq!(Manifest::from_json(&reparsed).unwrap(), m);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("star-manifest-{}", std::process::id()));
        let m = sample();
        m.save(&dir).unwrap();
        let loaded = Manifest::load(&dir).unwrap();
        assert_eq!(loaded, m);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lookup() {
        let m = sample();
        assert!(m.get("block").is_some());
        assert!(m.get("nope").is_none());
        assert_eq!(
            m.hlo_path(Path::new("artifacts"), "block").unwrap(),
            Path::new("artifacts").join("block.hlo.txt")
        );
    }
}
