//! The PJRT runtime — the only place numerics execute on the request
//! path.
//!
//! `python/compile/aot.py` lowers the L2 JAX model (which embeds the L1
//! Pallas kernels) to **HLO text** artifacts under `artifacts/`, plus a
//! `manifest.json` describing each entry point (name, file, input/output
//! shapes). This module loads the manifest, compiles every artifact on
//! the PJRT CPU client once at startup, and executes them with [`Mat`]
//! inputs. Python never runs at serving time.
//!
//! Interchange is HLO *text*, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see `/opt/xla-example`).

pub mod engine;
pub mod manifest;

pub use engine::{Engine, Execution};
pub use manifest::{ArtifactEntry, Manifest};
