//! The PJRT execution engine: compile HLO-text artifacts once, execute
//! many times from the L3 hot path.

use super::manifest::{ArtifactEntry, Manifest};
use crate::tensor::Mat;
use crate::Result;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One compiled entry point.
pub struct Execution {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl Execution {
    /// Execute with `Mat` inputs; returns one `Mat` per declared output.
    /// Output shapes come from the manifest (1-D outputs come back as
    /// single-row matrices).
    pub fn run(&self, inputs: &[Mat]) -> Result<Vec<Mat>> {
        anyhow::ensure!(
            inputs.len() == self.entry.inputs.len(),
            "{}: expected {} inputs, got {}",
            self.entry.name,
            self.entry.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (m, shape) in inputs.iter().zip(&self.entry.inputs) {
            let expect: usize = shape.iter().product();
            anyhow::ensure!(
                m.data.len() == expect,
                "{}: input element count {} != manifest {:?}",
                self.entry.name,
                m.data.len(),
                shape
            );
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(xla::Literal::vec1(&m.data).reshape(&dims)?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.entry.outputs.len(),
            "{}: got {} outputs, manifest says {}",
            self.entry.name,
            parts.len(),
            self.entry.outputs.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for (lit, shape) in parts.into_iter().zip(&self.entry.outputs) {
            let data = lit.to_vec::<f32>()?;
            let (rows, cols) = match shape.len() {
                0 => (1, 1),
                1 => (1, shape[0]),
                2 => (shape[0], shape[1]),
                _ => (shape[..shape.len() - 1].iter().product(), shape[shape.len() - 1]),
            };
            anyhow::ensure!(data.len() == rows * cols, "{}: output shape mismatch", self.entry.name);
            out.push(Mat::from_vec(rows, cols, data));
        }
        Ok(out)
    }
}

/// The engine: a PJRT CPU client plus every compiled artifact.
pub struct Engine {
    pub dir: PathBuf,
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executions: BTreeMap<String, Execution>,
}

impl Engine {
    /// Load and compile every artifact in `dir` (per its manifest).
    pub fn load_dir(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut engine = Engine {
            dir: dir.to_path_buf(),
            manifest: manifest.clone(),
            client,
            executions: BTreeMap::new(),
        };
        for entry in &manifest.entries {
            engine.compile_entry(entry)?;
        }
        Ok(engine)
    }

    /// Compile a single HLO-text file into an [`Execution`].
    fn compile_entry(&mut self, entry: &ArtifactEntry) -> Result<()> {
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.executions.insert(entry.name.clone(), Execution { entry: entry.clone(), exe });
        Ok(())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn names(&self) -> Vec<&str> {
        self.executions.keys().map(|s| s.as_str()).collect()
    }

    pub fn get(&self, name: &str) -> Option<&Execution> {
        self.executions.get(name)
    }

    /// Execute entry `name` on `inputs`.
    pub fn run(&self, name: &str, inputs: &[Mat]) -> Result<Vec<Mat>> {
        let exec = self
            .executions
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no artifact named {name:?} (have {:?})", self.names()))?;
        exec.run(inputs)
    }
}

/// True when an artifact directory with a manifest exists — integration
/// tests and examples use this to skip gracefully before `make artifacts`.
pub fn artifacts_available(dir: &Path) -> bool {
    dir.join("manifest.json").is_file()
}
