//! Summary statistics used by the bench harness and simulators.

/// Streaming summary: count, mean, variance (Welford), min/max.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    values: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY, values: Vec::new() }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.values.push(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Percentile (nearest-rank on sorted copy); p in [0,100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut v = self.values.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
        v[rank.min(v.len() - 1)]
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }
}

/// Geometric mean of positive values (the paper reports average gains as
/// geomeans across benchmarks).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Pearson correlation of two equal-length series.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    let _ = n;
    num / (dx.sqrt() * dy.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_moments() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        // Nearest-rank convention: rank round(0.5·7)=4 → value 5.
        assert_eq!(s.median(), 5.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let yn: Vec<f64> = ys.iter().map(|y| -y).collect();
        assert!((pearson(&xs, &yn) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_bounds() {
        let mut s = Summary::new();
        for i in 0..100 {
            s.add(i as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(100.0), 99.0);
        assert!((s.percentile(90.0) - 89.0).abs() <= 1.0);
    }
}
