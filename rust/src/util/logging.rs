//! Lightweight logging + wall-clock timing helpers (no `log` facade needed).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log levels in increasing verbosity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// Set the global log level (e.g. from `--verbose` / `STAR_LOG`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize level from the `STAR_LOG` environment variable if present.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("STAR_LOG") {
        let lvl = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            _ => Level::Info,
        };
        set_level(lvl);
    }
}

/// True if messages at `level` should be emitted.
pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Emit a log line to stderr with a level tag.
pub fn log(level: Level, msg: &str) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[star {tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, &format!($($t)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, &format!($($t)*)) };
}

#[macro_export]
macro_rules! debug_ {
    ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, &format!($($t)*)) };
}

/// RAII wall-clock timer; reports at Debug level on drop.
pub struct ScopedTimer {
    label: String,
    start: Instant,
}

impl ScopedTimer {
    pub fn new(label: &str) -> Self {
        ScopedTimer { label: label.to_string(), start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

impl Drop for ScopedTimer {
    fn drop(&mut self) {
        log(Level::Debug, &format!("{}: {:.3}s", self.label, self.elapsed_secs()));
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn timed_returns_result() {
        let (v, secs) = timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
