//! Heap-allocation metering for the zero-allocation hot-path contract.
//!
//! The pipeline engine ([`crate::pipeline::engine`]) claims its
//! steady-state tile core performs **zero heap allocations** once a
//! [`crate::pipeline::engine::TileWorkspace`] has warmed to its shape
//! class. That claim is only checkable if something counts allocations —
//! this module is that something: a [`CountingAllocator`] that wraps the
//! system allocator and bumps a **thread-local** counter on every
//! `alloc`/`realloc`/`alloc_zeroed`.
//!
//! The counter is thread-local on purpose: the engine samples it around
//! each tile's stage core, and worker threads must not see each other's
//! allocations in their windows (a global counter would make
//! multi-threaded runs overcount).
//!
//! The allocator is installed by **binaries**, not by this library — the
//! `star` binary, the plain-main bench drivers and the allocation-guard
//! integration test each declare
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: star::util::allocmeter::CountingAllocator =
//!     star::util::allocmeter::CountingAllocator;
//! ```
//!
//! When no counting allocator is installed the thread counter stays at
//! zero, every sampled window reads as zero, and [`installed`] reports
//! `false` so reports can say whether their `hot_path_allocs` field is a
//! real measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::{AtomicBool, Ordering};

static INSTALLED: AtomicBool = AtomicBool::new(false);

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

#[inline]
fn bump() {
    INSTALLED.store(true, Ordering::Relaxed);
    // `try_with`: allocations can happen while this thread's TLS is being
    // torn down; missing those is fine (nothing measures windows there).
    let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

/// A [`GlobalAlloc`] that counts allocations per thread and delegates all
/// actual work to [`System`]. Overhead is one `Cell` bump per allocation.
pub struct CountingAllocator;

// SAFETY: every method delegates verbatim to `System`; the only addition
// is the side-effect-free thread-local counter bump.
unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // A realloc is a fresh reservation from the hot path's point of
        // view: growing a Vec past its capacity must show up.
        bump();
        System.realloc(ptr, layout, new_size)
    }
}

/// Heap allocations made by **this thread** since it started (0 when no
/// [`CountingAllocator`] is installed). Sample before/after a region and
/// subtract to meter it.
pub fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

/// Whether a [`CountingAllocator`] has observed at least one allocation
/// in this process — i.e. whether allocation counts are real
/// measurements rather than vacuous zeros.
pub fn installed() -> bool {
    INSTALLED.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    // The unit-test binary does not install the counting allocator, so
    // the only thing testable here is the uninstalled behavior; the real
    // counting assertions live in `rust/tests/prop_workspace_reuse.rs`,
    // which installs it as its global allocator.
    #[test]
    fn uninstalled_counter_reads_zero() {
        if !super::installed() {
            assert_eq!(super::thread_allocs(), 0);
        }
    }
}
