//! Deterministic, seedable RNG (xoshiro256**). No external crates are
//! available offline, so simulators, workload generators and the mini
//! property-testing framework all share this implementation.

/// xoshiro256** PRNG. Deterministic across platforms; good statistical
/// quality for simulation workloads (not cryptographic).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create an RNG from a 64-bit seed via splitmix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        // splitmix64 never yields an all-zero state from distinct outputs,
        // but guard anyway: xoshiro must not be seeded with all zeros.
        let s = if s.iter().all(|&x| x == 0) { [1, 2, 3, 4] } else { s };
        Rng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → uniform double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f32 in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform usize in [0, n). `n` must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift rejection-free bound (bias negligible for sim use).
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform usize in [lo, hi) (hi > lo).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let mut u1 = self.f64();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std as f32.
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let mut u = self.f64();
        if u < 1e-300 {
            u = 1e-300;
        }
        -u.ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }

    /// Fork a child RNG (stream-split by label), independent of self's
    /// future outputs.
    pub fn fork(&mut self, label: u64) -> Rng {
        Rng::new(self.next_u64() ^ label.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(17);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }
}
