//! Small shared utilities: deterministic RNG, a JSON subset codec, summary
//! statistics, lightweight logging/timing helpers, and the thread-local
//! allocation meter behind the zero-allocation hot-path checks.

pub mod allocmeter;
pub mod json;
pub mod logging;
pub mod rng;
pub mod stats;

pub use rng::Rng;
pub use stats::Summary;

/// Round `x` up to the next multiple of `m` (m > 0).
pub fn round_up(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Integer ceiling division.
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Human-readable byte count (KiB/MiB/GiB with 1 decimal).
pub fn fmt_bytes(b: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.1} {}", UNITS[u])
}

/// Human readable SI count (K/M/G, 2 decimals).
pub fn fmt_si(x: f64) -> String {
    let ax = x.abs();
    if ax >= 1e12 {
        format!("{:.2}T", x / 1e12)
    } else if ax >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.2}K", x / 1e3)
    } else {
        format!("{x:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_up_basic() {
        assert_eq!(round_up(0, 4), 0);
        assert_eq!(round_up(1, 4), 4);
        assert_eq!(round_up(4, 4), 4);
        assert_eq!(round_up(5, 4), 8);
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 3), 1);
        assert_eq!(ceil_div(3, 3), 1);
        assert_eq!(ceil_div(4, 3), 2);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(512.0), "512.0 B");
        assert_eq!(fmt_bytes(2048.0), "2.0 KB");
        assert_eq!(fmt_si(1.5e9), "1.50G");
        assert_eq!(fmt_si(42.0), "42.00");
    }
}
