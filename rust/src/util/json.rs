//! Minimal JSON codec (parser + writer) — serde is unavailable offline.
//!
//! Supports the full JSON value grammar (objects, arrays, strings with
//! escapes, numbers, booleans, null). Used for the artifact manifest, config
//! files, and experiment-record output.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { s: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.s.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Pretty-print with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                }
                if !o.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// JSON parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    s: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.s.len() && matches!(self.s[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.s[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.s.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.s[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.s[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.s[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for t in ["null", "true", "false", "42", "-3.5", "\"hi\""] {
            let v = Json::parse(t).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64().unwrap(), 1.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("name", Json::str("star")),
            ("dims", Json::Arr(vec![Json::num(5.0), Json::num(5.0)])),
            ("ok", Json::Bool(true)),
        ]);
        let text = v.pretty();
        assert_eq!(Json::parse(&text).unwrap(), v);
        assert!(text.contains("\n"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::num(5.0).as_usize(), Some(5));
        assert_eq!(Json::num(5.5).as_usize(), None);
        assert_eq!(Json::num(-1.0).as_usize(), None);
    }
}
