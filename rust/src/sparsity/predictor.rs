//! The *pre-compute* stage: estimating the attention matrix Â cheaply.
//!
//! Cross-phase DLZS (Fig. 8a):
//!  * **Phase 1.1 (Key prediction)** — `K̂ = X · Ŵ_k` where `W_k` was
//!    pre-converted to LZ format offline; the datapath shifts X by
//!    `W − LZ(W_k)` — zero online conversion cost for the weights.
//!  * **Phase 1.2 (Attention prediction)** — `Â = Q̂ · K̂ᵀ` where **Q** (not
//!    K̂) is LZ-encoded, so the phase-1.1 estimation error in K̂ is not
//!    compounded by a second leading-zero truncation of the same values.
//!
//! Baselines: SLZS (both operands LZ-encoded, as FACT [9]) and a low-bit
//! (4-bit MSB) multiply predictor (the ablation baseline of Fig. 18a).

use crate::arith::dlzs::{dlzs_mul, slzs_mul};
use crate::arith::lanes::{I64x8, KernelPath, LANES};
use crate::arith::{IntBits, LzCode, OpCounter, OpKind, QuantMat};
use crate::tensor::Mat;

/// Prediction arithmetic scheme.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredictScheme {
    /// Differential LZ: encode one operand only (the paper's scheme).
    Dlzs,
    /// Symmetric LZ: encode both operands (FACT-style baseline).
    Slzs,
    /// Low-precision multiply (4-bit MSB), no log-domain approximation.
    LowBitMul,
}

/// Storage width for a prediction datapath of magnitude bitwidth `W` —
/// the one rule every prediction path (whole-tensor [`Predictor`] and the
/// per-row KV-cache operands in [`crate::kvcache`]) must share.
pub fn bits_for(w: u32) -> IntBits {
    match w {
        0..=3 => IntBits::Int4,
        4..=7 => IntBits::Int8,
        _ => IntBits::Int16,
    }
}

/// Configured predictor for the pre-compute stage.
#[derive(Clone, Debug)]
pub struct Predictor {
    pub scheme: PredictScheme,
    /// Quantized magnitude bitwidth W of the prediction datapath.
    pub w: u32,
}

impl Predictor {
    pub fn new(scheme: PredictScheme, w: u32) -> Predictor {
        Predictor { scheme, w }
    }

    /// The paper's default: DLZS on an 8-bit (W = 7) prediction path.
    pub fn star_default() -> Predictor {
        Predictor::new(PredictScheme::Dlzs, 7)
    }

    fn bits(&self) -> IntBits {
        bits_for(self.w)
    }

    /// Estimate `a · bᵀ` (a: [m, d], b: [n, d]) with the configured scheme.
    /// Returns scores in the same scale as the exact product so downstream
    /// top-k thresholds are comparable across schemes.
    pub fn approx_scores(&self, a: &Mat, b: &Mat, c: &mut OpCounter) -> Mat {
        self.prepare(a, b, c).score_rows(0, a.rows, c)
    }

    /// One-time operand preparation for tiled prediction: quantize both
    /// sides (scale from the FULL tensors) and LZ-encode whichever sides
    /// the scheme converts, charging the conversion ops/traffic once.
    /// Per-query-tile work then happens in [`PreparedPredict::score_rows`],
    /// whose rows are bit-identical to the corresponding rows of a whole-
    /// matrix [`Predictor::approx_scores`] call — the property that makes
    /// the cross-stage tiled pipeline numerically equal to stage-serial
    /// execution.
    pub fn prepare(&self, a: &Mat, b: &Mat, c: &mut OpCounter) -> PreparedPredict {
        let bits = self.bits();
        assert_eq!(a.cols, b.cols);
        let qa = QuantMat::quantize(a, bits);
        let qb = QuantMat::quantize(b, bits);
        let (m, n, d) = (a.rows, b.rows, a.cols);
        let scale = qa.scale * qb.scale;

        // Keep only the operands the scheme's datapath actually reads —
        // the prepared struct is shared across worker threads for the
        // whole tiled run.
        let ops = match self.scheme {
            PredictScheme::Dlzs => {
                // Differential: LZ-encode ONE side (the `a` side, playing the
                // role of Q in phase 1.2). One LZ encode per element of a.
                let a_codes = qa.q.iter().map(|&x| LzCode::encode(x, self.w)).collect();
                c.tally(OpKind::LzEncode, (m * d) as u64);
                // Traffic: DLZS loads the compact LZ codes (~4+1 bits) for
                // the encoded side instead of full W+1-bit operands.
                c.sram((m * d) as u64); // ≈1 byte/code
                c.sram((n * d * 2) as u64);
                PreparedOps::Dlzs { a_codes, qb }
            }
            PredictScheme::Slzs => {
                let a_codes = qa.q.iter().map(|&x| LzCode::encode(x, self.w)).collect();
                let b_codes = qb.q.iter().map(|&x| LzCode::encode(x, self.w)).collect();
                // Symmetric: both operand sets pay conversion.
                c.tally(OpKind::LzEncode, ((m + n) * d) as u64);
                // SLZS must fetch full-width operands for the encode step.
                c.sram((m * d * 2) as u64);
                c.sram((n * d * 2) as u64);
                PreparedOps::Slzs { a_codes, b_codes }
            }
            PredictScheme::LowBitMul => {
                let ta = qa.truncate_to_msb(4.min(self.w));
                let tb = qb.truncate_to_msb(4.min(self.w));
                c.sram((m * d * 2) as u64);
                c.sram((n * d * 2) as u64);
                PreparedOps::LowBit { ta, tb }
            }
        };
        PreparedPredict { rows: m, keys: n, d, ops, scale }
    }

    /// Cross-phase prediction (Fig. 8a): phase 1.1 estimates K̂ = X·W_k with
    /// pre-converted LZ weights (no online conversion), phase 1.2 estimates
    /// Â = Q·K̂ᵀ with LZ-encoded Q. Returns (K̂, Â).
    pub fn cross_phase(
        &self,
        x: &Mat,  // [S, H_in]
        wk: &Mat, // [H_in, d]
        q: &Mat,  // [T, d]
        c: &mut OpCounter,
    ) -> (Mat, Mat) {
        let khat = self.khat_phase(x, wk, c);
        // Phase 1.2: LZ-encode Q (NOT K̂) to avoid compounding the phase-1.1
        // approximation error (cross-phase advantage #2).
        let ahat = self.approx_scores(q, &khat, c);
        (khat, ahat)
    }

    /// Phase 1.1 alone: estimate K̂ = X·W_k with the pre-converted LZ
    /// weights. The tiled pipeline runs this once as a prologue and feeds
    /// K̂ into a [`Predictor::prepare`] for per-tile phase-1.2 scoring.
    pub fn khat_phase(&self, x: &Mat, wk: &Mat, c: &mut OpCounter) -> Mat {
        let bits = self.bits();
        let (s, h) = (x.rows, x.cols);
        let d = wk.cols;
        assert_eq!(wk.rows, h);

        let qx = QuantMat::quantize(x, bits);
        let qw = QuantMat::quantize(wk, bits);
        // W_k codes are produced OFFLINE: no LzEncode ops are charged here
        // (cross-phase advantage #1) and only ~5-bit codes are loaded.
        let w_codes: Vec<LzCode> = qw.q.iter().map(|&v| LzCode::encode(v, self.w)).collect();
        c.sram((h * d) as u64); // compact code loads
        c.sram((s * h * 2) as u64);

        let mut khat = Mat::zeros(s, d);
        c.tally(OpKind::Shift, (s * h * d) as u64);
        c.tally(OpKind::Add, (s * h * d) as u64);
        for i in 0..s {
            for j in 0..d {
                let mut acc = 0i64;
                for p in 0..h {
                    acc += dlzs_mul(qx.at(i, p), w_codes[p * d + j]);
                }
                *khat.at_mut(i, j) = acc as f32 * (qx.scale * qw.scale);
            }
        }
        khat
    }
}

/// Per-scheme operands the tiled datapath reads.
enum PreparedOps {
    /// Differential: LZ codes of the `a` side, quantized `b` side.
    Dlzs { a_codes: Vec<LzCode>, qb: QuantMat },
    /// Symmetric: LZ codes of both sides.
    Slzs { a_codes: Vec<LzCode>, b_codes: Vec<LzCode> },
    /// Low-bit multiply: MSB-truncated operands.
    LowBit { ta: QuantMat, tb: QuantMat },
}

/// Quantized + LZ-encoded operands ready for tiled score estimation.
/// Immutable and `Sync`: the pipeline shares one across worker threads.
pub struct PreparedPredict {
    rows: usize,
    keys: usize,
    d: usize,
    ops: PreparedOps,
    scale: f32,
}

impl PreparedPredict {
    /// Number of `a` rows (query rows) available.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of `b` rows (key rows) scored per query row.
    pub fn keys(&self) -> usize {
        self.keys
    }

    /// Estimate rows `lo..hi` of `Â = a·bᵀ`. Row `i` of the result is
    /// bit-identical to row `lo + i` of the whole-matrix estimate.
    pub fn score_rows(&self, lo: usize, hi: usize, c: &mut OpCounter) -> Mat {
        let mut out = Mat::zeros(hi.saturating_sub(lo), self.keys);
        self.score_rows_into(lo, hi, c, &mut out);
        out
    }

    /// [`PreparedPredict::score_rows`] writing into a caller-provided
    /// buffer — the tile engine's allocation-free predict stage.
    pub fn score_rows_into(&self, lo: usize, hi: usize, c: &mut OpCounter, out: &mut Mat) {
        self.score_block_into(lo, hi, 0, self.keys, c, out)
    }

    /// Estimate the `(lo..hi) × (key_lo..key_hi)` block of `Â = a·bᵀ`.
    /// Element `(i, j)` is bit-identical to element `(lo + i, key_lo +
    /// j)` of the whole-matrix estimate — each estimate is an
    /// independent dot product over operands quantized with the *global*
    /// scales frozen at [`Predictor::prepare`]. This is what lets the
    /// sequence-sharded pipeline score a key sub-range per worker
    /// without changing a single bit of the prediction.
    pub fn score_block(
        &self,
        lo: usize,
        hi: usize,
        key_lo: usize,
        key_hi: usize,
        c: &mut OpCounter,
    ) -> Mat {
        let mut out = Mat::zeros(hi.saturating_sub(lo), key_hi.saturating_sub(key_lo));
        self.score_block_into(lo, hi, key_lo, key_hi, c, &mut out);
        out
    }

    /// [`PreparedPredict::score_block`] writing into a caller-provided
    /// buffer (which is [`Mat::reset`] to the block shape — no
    /// allocation once it has the capacity). This is the only scoring
    /// kernel; the allocating entry points wrap it, so buffered and
    /// fresh estimates are bit-identical by construction. Dispatches on
    /// the `simd` cargo feature ([`KernelPath::active`]).
    pub fn score_block_into(
        &self,
        lo: usize,
        hi: usize,
        key_lo: usize,
        key_hi: usize,
        c: &mut OpCounter,
        out: &mut Mat,
    ) {
        self.score_block_into_with(lo, hi, key_lo, key_hi, c, out, KernelPath::active())
    }

    /// [`PreparedPredict::score_block_into`] with an explicit kernel
    /// path, for benches and parity tests.
    ///
    /// Every scheme accumulates its per-element products **exactly in
    /// i64**, and integer addition is associative — so the lane spelling
    /// (8 independent accumulators over `d`, combined by an exact
    /// horizontal sum, scalar remainder lanes) is unconditionally
    /// bit-identical to the scalar one, NaN/∞ questions never arising
    /// until the single final `as f32 * scale` both spellings share. Op
    /// accounting is tallied per block before either loop and is
    /// path-independent.
    #[allow(clippy::too_many_arguments)]
    pub fn score_block_into_with(
        &self,
        lo: usize,
        hi: usize,
        key_lo: usize,
        key_hi: usize,
        c: &mut OpCounter,
        out: &mut Mat,
        path: KernelPath,
    ) {
        let d = self.d;
        assert!(lo <= hi && hi <= self.rows, "tile {lo}..{hi} out of range");
        assert!(key_lo <= key_hi && key_hi <= self.keys, "keys {key_lo}..{key_hi} out of range");
        let m = hi - lo;
        let n = key_hi - key_lo;
        out.reset(m, n);
        // 8 independent i64 accumulators over d, exact combine, scalar tail.
        let lane_dot = |term: &dyn Fn(usize) -> i64| -> i64 {
            let full_d = d - d % LANES;
            let mut acc = I64x8::zero();
            for p0 in (0..full_d).step_by(LANES) {
                let mut lane = [0i64; LANES];
                for (l, v) in lane.iter_mut().enumerate() {
                    *v = term(p0 + l);
                }
                acc = acc.add(I64x8(lane));
            }
            let mut sum = acc.hsum();
            for p in full_d..d {
                sum += term(p);
            }
            sum
        };
        match &self.ops {
            PreparedOps::Dlzs { a_codes, qb } => {
                // Per product: one shift, one add (accumulate).
                c.tally(OpKind::Shift, (m * n * d) as u64);
                c.tally(OpKind::Add, (m * n * d) as u64);
                for i in 0..m {
                    let arow = &a_codes[(lo + i) * d..(lo + i + 1) * d];
                    for j in 0..n {
                        let brow = qb.row(key_lo + j);
                        let acc = match path {
                            KernelPath::Scalar => {
                                let mut acc = 0i64;
                                for p in 0..d {
                                    acc += dlzs_mul(brow[p], arow[p]);
                                }
                                acc
                            }
                            KernelPath::Lanes => lane_dot(&|p| dlzs_mul(brow[p], arow[p])),
                        };
                        *out.at_mut(i, j) = acc as f32 * self.scale;
                    }
                }
            }
            PreparedOps::Slzs { a_codes, b_codes } => {
                c.tally(OpKind::Shift, (m * n * d) as u64);
                c.tally(OpKind::Add, (m * n * d) as u64);
                for i in 0..m {
                    let arow = &a_codes[(lo + i) * d..(lo + i + 1) * d];
                    for j in 0..n {
                        let brow = &b_codes[(key_lo + j) * d..(key_lo + j + 1) * d];
                        let acc = match path {
                            KernelPath::Scalar => {
                                let mut acc = 0i64;
                                for p in 0..d {
                                    acc += slzs_mul(arow[p], brow[p]);
                                }
                                acc
                            }
                            KernelPath::Lanes => lane_dot(&|p| slzs_mul(arow[p], brow[p])),
                        };
                        *out.at_mut(i, j) = acc as f32 * self.scale;
                    }
                }
            }
            PreparedOps::LowBit { ta, tb } => {
                c.tally(OpKind::Mul, (m * n * d) as u64);
                c.tally(OpKind::Add, (m * n * d) as u64);
                for i in 0..m {
                    let arow = ta.row(lo + i);
                    for j in 0..n {
                        let brow = tb.row(key_lo + j);
                        let acc = match path {
                            KernelPath::Scalar => {
                                let mut acc = 0i64;
                                for p in 0..d {
                                    acc += arow[p] as i64 * brow[p] as i64;
                                }
                                acc
                            }
                            KernelPath::Lanes => lane_dot(&|p| arow[p] as i64 * brow[p] as i64),
                        };
                        *out.at_mut(i, j) = acc as f32 * self.scale;
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::topk_indices;
    use crate::util::Rng;

    fn mats(seed: u64, m: usize, n: usize, d: usize) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        (Mat::randn(m, d, 1.0, &mut rng), Mat::randn(n, d, 1.0, &mut rng))
    }

    #[test]
    fn dlzs_scores_correlate_with_exact() {
        let (a, b) = mats(1, 16, 64, 32);
        let exact = a.matmul(&b.transpose());
        let mut c = OpCounter::new();
        let est = Predictor::star_default().approx_scores(&a, &b, &mut c);
        // Rank fidelity is what matters for top-k: check per-row hit rate.
        let mut hits = 0usize;
        let k = 16;
        for i in 0..a.rows {
            let te = topk_indices(exact.row(i), k);
            let tp = topk_indices(est.row(i), k);
            hits += te.iter().filter(|x| tp.contains(x)).count();
        }
        let rate = hits as f64 / (a.rows * k) as f64;
        assert!(rate > 0.8, "dlzs top-k hit rate {rate}");
    }

    #[test]
    fn dlzs_beats_slzs_on_hit_rate() {
        let (a, b) = mats(2, 24, 96, 32);
        let exact = a.matmul(&b.transpose());
        let k = 19; // top-20%
        let mut rate = |scheme| {
            let mut c = OpCounter::new();
            let est = Predictor::new(scheme, 7).approx_scores(&a, &b, &mut c);
            let mut hits = 0usize;
            for i in 0..a.rows {
                let te = topk_indices(exact.row(i), k);
                let tp = topk_indices(est.row(i), k);
                hits += te.iter().filter(|x| tp.contains(x)).count();
            }
            hits as f64 / (a.rows * k) as f64
        };
        let d = rate(PredictScheme::Dlzs);
        let s = rate(PredictScheme::Slzs);
        assert!(d > s, "dlzs {d} !> slzs {s}");
    }

    #[test]
    fn dlzs_is_multiplier_free() {
        let (a, b) = mats(3, 4, 8, 16);
        let mut c = OpCounter::new();
        Predictor::star_default().approx_scores(&a, &b, &mut c);
        assert_eq!(c.mul, 0);
        assert!(c.shift > 0);
        // Differential: encodes only the a-side.
        assert_eq!(c.lz_encode, (4 * 16) as u64);
    }

    #[test]
    fn slzs_pays_double_conversion() {
        let (a, b) = mats(4, 4, 8, 16);
        let mut cd = OpCounter::new();
        let mut cs = OpCounter::new();
        Predictor::new(PredictScheme::Dlzs, 7).approx_scores(&a, &b, &mut cd);
        Predictor::new(PredictScheme::Slzs, 7).approx_scores(&a, &b, &mut cs);
        assert_eq!(cs.lz_encode, ((4 + 8) * 16) as u64);
        assert!(cd.lz_encode < cs.lz_encode);
        // ...and heavier operand traffic (full-width loads for both sides).
        assert!(cd.sram_bytes < cs.sram_bytes);
    }

    #[test]
    fn cross_phase_produces_usable_khat_and_ahat() {
        let mut rng = Rng::new(5);
        let (s, h, d, t) = (48, 32, 16, 8);
        let x = Mat::randn(s, h, 1.0, &mut rng);
        let wk = Mat::randn(h, d, 0.3, &mut rng);
        let q = Mat::randn(t, d, 1.0, &mut rng);
        let k_true = x.matmul(&wk);
        let a_true = q.matmul(&k_true.transpose());
        let mut c = OpCounter::new();
        let (khat, ahat) = Predictor::star_default().cross_phase(&x, &wk, &q, &mut c);
        assert!(khat.rel_err(&k_true) < 0.5, "khat rel err {}", khat.rel_err(&k_true));
        // Top-k fidelity of the end-to-end estimate.
        let k = 12;
        let mut hits = 0usize;
        for i in 0..t {
            let te = topk_indices(a_true.row(i), k);
            let tp = topk_indices(ahat.row(i), k);
            hits += te.iter().filter(|x| tp.contains(x)).count();
        }
        let rate = hits as f64 / (t * k) as f64;
        assert!(rate > 0.7, "cross-phase hit rate {rate}");
        // Cross-phase charges no online conversion for W_k.
        assert_eq!(c.lz_encode, (t * d) as u64);
        assert_eq!(c.mul, 0);
    }

    #[test]
    fn tiled_score_rows_match_whole_matrix_estimate() {
        // The tiled-pipeline contract: per-tile estimates are row slices
        // of the whole-matrix estimate, bit for bit, for every scheme.
        for scheme in [PredictScheme::Dlzs, PredictScheme::Slzs, PredictScheme::LowBitMul] {
            let (a, b) = mats(7, 20, 48, 16);
            let pred = Predictor::new(scheme, 7);
            let mut c = OpCounter::new();
            let full = pred.approx_scores(&a, &b, &mut c);
            let mut ct = OpCounter::new();
            let prep = pred.prepare(&a, &b, &mut ct);
            assert_eq!((prep.rows(), prep.keys()), (20, 48));
            for lo in (0..20).step_by(6) {
                let hi = (lo + 6).min(20);
                let tile = prep.score_rows(lo, hi, &mut ct);
                for i in 0..(hi - lo) {
                    assert_eq!(tile.row(i), full.row(lo + i), "{scheme:?} row {}", lo + i);
                }
            }
            // Tiled accounting sums to the whole-matrix accounting.
            assert_eq!(ct, c, "{scheme:?} op accounting drifted under tiling");
        }
    }

    #[test]
    fn key_blocked_scores_match_whole_matrix_estimate() {
        // The sharded-pipeline contract: scoring a key sub-range per
        // worker slices the whole-matrix estimate bit for bit, and the
        // per-product accounting sums to the whole-matrix accounting.
        for scheme in [PredictScheme::Dlzs, PredictScheme::Slzs, PredictScheme::LowBitMul] {
            let (a, b) = mats(8, 20, 50, 16);
            let pred = Predictor::new(scheme, 7);
            let mut c = OpCounter::new();
            let full = pred.approx_scores(&a, &b, &mut c);
            let mut ct = OpCounter::new();
            let prep = pred.prepare(&a, &b, &mut ct);
            for (key_lo, key_hi) in [(0usize, 17usize), (17, 40), (40, 50)] {
                let block = prep.score_block(3, 11, key_lo, key_hi, &mut ct);
                for i in 0..8 {
                    for j in 0..(key_hi - key_lo) {
                        assert_eq!(
                            block.at(i, j),
                            full.at(3 + i, key_lo + j),
                            "{scheme:?} ({i},{j})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn score_block_into_reuses_dirty_buffers_bit_identically() {
        // The workspace contract: scoring into a dirty, wrong-shaped
        // buffer equals a fresh score_block, ops included.
        for scheme in [PredictScheme::Dlzs, PredictScheme::Slzs, PredictScheme::LowBitMul] {
            let (a, b) = mats(9, 12, 33, 16);
            let pred = Predictor::new(scheme, 7);
            let mut c = OpCounter::new();
            let prep = pred.prepare(&a, &b, &mut c);
            let mut dirty = Mat::randn(5, 5, 1.0, &mut Rng::new(1));
            let mut cw = OpCounter::new();
            let want = prep.score_block(2, 9, 10, 30, &mut cw);
            let mut cg = OpCounter::new();
            prep.score_block_into(2, 9, 10, 30, &mut cg, &mut dirty);
            assert_eq!(dirty, want, "{scheme:?}");
            assert_eq!(cg, cw, "{scheme:?} ops drift");
        }
    }

    #[test]
    fn score_lanes_path_is_bit_identical_to_scalar() {
        // Remainder-lane d (13, 9) and lane-multiple d (16), every scheme,
        // with op accounting equal on both paths.
        for scheme in [PredictScheme::Dlzs, PredictScheme::Slzs, PredictScheme::LowBitMul] {
            for d in [9usize, 13, 16] {
                let (a, b) = mats(11 + d as u64, 10, 27, d);
                let pred = Predictor::new(scheme, 7);
                let mut c = OpCounter::new();
                let prep = pred.prepare(&a, &b, &mut c);
                let mut os = Mat::randn(3, 3, 1.0, &mut Rng::new(2)); // dirty
                let mut ol = Mat::randn(4, 1, 1.0, &mut Rng::new(3)); // dirty
                let mut cs = OpCounter::new();
                let mut cl = OpCounter::new();
                prep.score_block_into_with(1, 9, 5, 22, &mut cs, &mut os, KernelPath::Scalar);
                prep.score_block_into_with(1, 9, 5, 22, &mut cl, &mut ol, KernelPath::Lanes);
                assert_eq!(os, ol, "{scheme:?} d={d}");
                assert_eq!(cs, cl, "{scheme:?} d={d} ops drift");
            }
        }
    }

    #[test]
    fn lowbit_baseline_uses_multipliers() {
        let (a, b) = mats(6, 4, 8, 16);
        let mut c = OpCounter::new();
        Predictor::new(PredictScheme::LowBitMul, 7).approx_scores(&a, &b, &mut c);
        assert!(c.mul > 0);
        assert_eq!(c.shift, 0);
    }
}
