//! The *top-k* stage: selecting the vital Q–K pairs from the estimated Â.
//!
//! * [`vanilla_topk`] — the baseline most DS accelerators use: per-row
//!   selection where extracting each of the `S·k` winners scans the whole
//!   remaining row — O(S·S·k) comparisons per row (Sec. III-A(1)).
//! * [`sads_topk`] — Sphere-search Aided Distributed Sorting (Sec. IV-B):
//!   the row splits into `n` sub-segments; each finds its local max
//!   (`len−1` comparisons), eliminates every element with `Δ = max − x > r`
//!   (one comparison each — justified by Eq. 5: softmax(x) < e^−Δ), and
//!   runs the selection passes only over the surviving ρ fraction:
//!   O(S·S·k·ρ/n) total. Survivor lists merge into one descending order for
//!   SU-FA.

use crate::arith::{OpCounter, OpKind};

/// SADS configuration.
#[derive(Clone, Copy, Debug)]
pub struct SadsParams {
    /// Number of sub-segments n.
    pub segments: usize,
    /// Sphere radius r (score units); elements with max − x > r are pruned.
    pub radius: f32,
}

impl Default for SadsParams {
    fn default() -> Self {
        SadsParams { segments: 4, radius: 5.0 }
    }
}

/// Statistics from one SADS row pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct SadsStats {
    /// Fraction of elements surviving the sphere filter (ρ).
    pub rho: f64,
    /// Comparisons spent (same number tallied into the OpCounter).
    pub comparisons: u64,
}

/// Baseline per-row top-k: repeated max-extraction scans (what "selecting
/// each element requires O(S) operations" describes). Returns indices in
/// descending score order.
pub fn vanilla_topk(row: &[f32], k: usize, c: &mut OpCounter) -> Vec<usize> {
    let s = row.len();
    let k = k.min(s);
    let mut taken = vec![false; s];
    let mut out = Vec::with_capacity(k);
    for _ in 0..k {
        let mut best = usize::MAX;
        let mut best_v = f32::NEG_INFINITY;
        for (j, &x) in row.iter().enumerate() {
            if !taken[j] {
                c.tally(OpKind::Cmp, 1);
                if x > best_v {
                    best_v = x;
                    best = j;
                }
            }
        }
        taken[best] = true;
        out.push(best);
    }
    out
}

/// SADS: distributed per-segment selection with sphere-radius early
/// termination. Returns (indices in descending estimated-score order,
/// stats). Each segment contributes ⌈k/n⌉ winners (clipped to its size);
/// the result is truncated to `k`.
pub fn sads_topk(
    row: &[f32],
    k: usize,
    p: &SadsParams,
    c: &mut OpCounter,
) -> (Vec<usize>, SadsStats) {
    let s = row.len();
    let k = k.min(s);
    if k == 0 || s == 0 {
        return (Vec::new(), SadsStats::default());
    }
    let n = p.segments.max(1).min(s);
    let seg_len = s.div_ceil(n);
    let per_seg = k.div_ceil(n);

    let mut cmp_count = 0u64;
    let mut survivors_total = 0usize;
    // Per-segment winners, each list already descending.
    let mut seg_lists: Vec<Vec<(f32, usize)>> = Vec::with_capacity(n);

    for seg in 0..n {
        let lo = seg * seg_len;
        if lo >= s {
            break;
        }
        let hi = (lo + seg_len).min(s);
        let len = hi - lo;

        // 1) Local max: len − 1 comparisons.
        let mut mx = f32::NEG_INFINITY;
        for &x in &row[lo..hi] {
            if x > mx {
                mx = x;
            }
        }
        cmp_count += (len - 1) as u64;

        // 2) Sphere filter: one comparison per element against (max − r).
        let floor = mx - p.radius;
        let feasible: Vec<usize> = (lo..hi).filter(|&j| row[j] >= floor).collect();
        cmp_count += len as u64;
        survivors_total += feasible.len();

        // 3) Selection passes restricted to the feasible region.
        let take = per_seg.min(feasible.len());
        let mut taken = vec![false; feasible.len()];
        let mut winners = Vec::with_capacity(take);
        for _ in 0..take {
            let mut bi = usize::MAX;
            let mut bv = f32::NEG_INFINITY;
            for (fi, &j) in feasible.iter().enumerate() {
                if !taken[fi] {
                    cmp_count += 1;
                    if row[j] > bv {
                        bv = row[j];
                        bi = fi;
                    }
                }
            }
            taken[bi] = true;
            winners.push((row[feasible[bi]], feasible[bi]));
        }
        seg_lists.push(winners);
    }

    // 4) n-way merge of descending lists → global descending order (the
    //    order SU-FA consumes). One comparison per output per live list.
    let mut cursors = vec![0usize; seg_lists.len()];
    let mut merged: Vec<usize> = Vec::with_capacity(k);
    while merged.len() < k {
        let mut best_list = usize::MAX;
        let mut best_v = f32::NEG_INFINITY;
        for (li, list) in seg_lists.iter().enumerate() {
            if cursors[li] < list.len() {
                cmp_count += 1;
                if list[cursors[li]].0 > best_v {
                    best_v = list[cursors[li]].0;
                    best_list = li;
                }
            }
        }
        if best_list == usize::MAX {
            break; // all lists exhausted (aggressive pruning)
        }
        merged.push(seg_lists[best_list][cursors[best_list]].1);
        cursors[best_list] += 1;
    }

    c.tally(OpKind::Cmp, cmp_count);
    let stats = SadsStats { rho: survivors_total as f64 / s as f64, comparisons: cmp_count };
    (merged, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::topk_indices;
    use crate::util::Rng;

    fn rand_row(s: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..s).map(|_| rng.normal_f32(0.0, 2.0)).collect()
    }

    #[test]
    fn vanilla_matches_oracle() {
        let row = rand_row(200, 1);
        let mut c = OpCounter::new();
        let got = vanilla_topk(&row, 20, &mut c);
        assert_eq!(got, topk_indices(&row, 20));
        // Comparison count ≈ k·S (minus the extracted ones).
        assert!(c.cmp as usize >= 20 * (200 - 20));
    }

    #[test]
    fn sads_descending_order() {
        let row = rand_row(256, 2);
        let mut c = OpCounter::new();
        let (sel, _) = sads_topk(&row, 32, &SadsParams::default(), &mut c);
        for w in sel.windows(2) {
            assert!(row[w[0]] >= row[w[1]], "not descending");
        }
        assert_eq!(sel.len(), 32);
        let mut uniq = sel.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 32, "duplicates in selection");
    }

    #[test]
    fn sads_recall_high_on_dispersed_rows() {
        // Type-II-like rows (dispersed maxima) are SADS's design target.
        let mut total_hits = 0usize;
        let mut total = 0usize;
        for seed in 0..10u64 {
            let row = rand_row(512, 100 + seed);
            let k = 64;
            let truth = topk_indices(&row, k);
            let mut c = OpCounter::new();
            let (sel, _) = sads_topk(&row, k, &SadsParams::default(), &mut c);
            total_hits += truth.iter().filter(|t| sel.contains(t)).count();
            total += k;
        }
        let recall = total_hits as f64 / total as f64;
        assert!(recall > 0.85, "sads recall {recall}");
    }

    #[test]
    fn sads_far_fewer_comparisons_than_vanilla() {
        let row = rand_row(1024, 3);
        let k = 256; // k-ratio 0.25, the paper's complexity example
        let mut cv = OpCounter::new();
        vanilla_topk(&row, k, &mut cv);
        let mut cs = OpCounter::new();
        let (_, stats) = sads_topk(&row, k, &SadsParams::default(), &mut cs);
        let ratio = cs.cmp as f64 / cv.cmp as f64;
        // Paper: ~10% of standard sorting for S=1024, n=4, k=0.25, ρ≈0.4.
        assert!(ratio < 0.35, "sads/vanilla cmp ratio {ratio} (rho={})", stats.rho);
    }

    #[test]
    fn radius_controls_rho() {
        let row = rand_row(512, 4);
        let mut c = OpCounter::new();
        let (_, tight) = sads_topk(&row, 64, &SadsParams { segments: 4, radius: 1.0 }, &mut c);
        let (_, loose) = sads_topk(&row, 64, &SadsParams { segments: 4, radius: 20.0 }, &mut c);
        assert!(tight.rho < loose.rho);
        assert!((loose.rho - 1.0).abs() < 1e-9, "radius 20σ keeps everything");
    }

    #[test]
    fn more_segments_fewer_comparisons() {
        let row = rand_row(1024, 5);
        let mut cmp_for = |n: usize| {
            let mut c = OpCounter::new();
            sads_topk(&row, 128, &SadsParams { segments: n, radius: 5.0 }, &mut c);
            c.cmp
        };
        let c2 = cmp_for(2);
        let c8 = cmp_for(8);
        assert!(c8 < c2, "n=8 ({c8}) !< n=2 ({c2})");
    }

    #[test]
    fn aggressive_radius_may_underfill_but_never_panics() {
        // A row with one huge spike: radius filters everything else.
        let mut row = vec![0.0f32; 128];
        row[7] = 100.0;
        let mut c = OpCounter::new();
        let (sel, stats) = sads_topk(&row, 32, &SadsParams { segments: 4, radius: 5.0 }, &mut c);
        assert!(sel.contains(&7));
        // Segments without the spike keep elements within r of their own
        // local max (all zeros → all survive), so underfill need not occur;
        // the spike's own segment prunes hard.
        assert!(stats.rho <= 1.0);
    }

    #[test]
    fn edge_cases() {
        let mut c = OpCounter::new();
        assert!(sads_topk(&[], 4, &SadsParams::default(), &mut c).0.is_empty());
        let (one, _) = sads_topk(&[1.0], 4, &SadsParams::default(), &mut c);
        assert_eq!(one, vec![0]);
        let row = rand_row(16, 6);
        let (all, _) = sads_topk(&row, 16, &SadsParams { segments: 4, radius: 1e9 }, &mut c);
        assert_eq!(all.len(), 16);
    }
}
