//! The *top-k* stage: selecting the vital Q–K pairs from the estimated Â.
//!
//! * [`vanilla_topk`] — the baseline most DS accelerators use: per-row
//!   selection where extracting each of the `S·k` winners scans the whole
//!   remaining row — O(S·S·k) comparisons per row (Sec. III-A(1)).
//! * [`sads_topk`] — Sphere-search Aided Distributed Sorting (Sec. IV-B):
//!   the row splits into `n` sub-segments; each finds its local max
//!   (`len−1` comparisons), eliminates every element with `Δ = max − x > r`
//!   (one comparison each — justified by Eq. 5: softmax(x) < e^−Δ), and
//!   runs the selection passes only over the surviving ρ fraction:
//!   O(S·S·k·ρ/n) total. Survivor lists merge into one descending order for
//!   SU-FA.
//!
//! SADS is *distributed by construction*: the per-segment pass
//! ([`sads_segment_winners`]) reads only its own segment's scores, and
//! the merge ([`sads_merge`]) reads only the per-segment winner lists.
//! [`sads_topk`] composes the two on one core; the sequence-sharded
//! pipeline ([`crate::pipeline::ShardedPipeline`]) runs the segment
//! passes on the workers owning those key ranges and the merge at the
//! query block's home worker — same functions, same comparisons, same
//! selection, bit for bit. [`merge_topk_candidates`] is the analogous
//! merge pass for the exact (vanilla) engine.
//!
//! # Select-into-arena entry points
//!
//! Every engine has two spellings of the same selection:
//!
//! * the classic allocating one (`vanilla_topk`, `sads_topk`, …), and
//! * an `_into` variant writing into caller-owned buffers plus a
//!   reusable [`TopkScratch`] — the hot path of the allocation-free tile
//!   engine ([`crate::pipeline::engine`]).
//!
//! Each pair shares one private core (`segment_pass`, `merge_pass`, the
//! extraction scans), so the buffered and allocating spellings cannot
//! drift: identical selections, identical orders, identical comparison
//! counts, enforced again by the unit tests at the bottom of this file.

use crate::arith::lanes::{F32x8, KernelPath, LANES};
use crate::arith::{OpCounter, OpKind};

/// SADS configuration.
#[derive(Clone, Copy, Debug)]
pub struct SadsParams {
    /// Number of sub-segments n.
    pub segments: usize,
    /// Sphere radius r (score units); elements with max − x > r are pruned.
    pub radius: f32,
}

impl Default for SadsParams {
    fn default() -> Self {
        SadsParams { segments: 4, radius: 5.0 }
    }
}

/// Statistics from one SADS row pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct SadsStats {
    /// Fraction of elements surviving the sphere filter (ρ).
    pub rho: f64,
    /// Comparisons spent (same number tallied into the OpCounter).
    pub comparisons: u64,
}

/// Reusable scratch for the `_into` top-k entry points: extraction
/// flags, the SADS sphere-filter survivor list, the flat per-segment
/// winner arena and the merge cursors. One instance per worker thread
/// ([`crate::pipeline::engine::TileWorkspace`] owns one), reused across
/// rows, tiles and requests — buffers only ever grow, so steady-state
/// selection performs zero heap allocations.
#[derive(Clone, Debug, Default)]
pub struct TopkScratch {
    /// Extraction flags over the scan domain (row or survivor list).
    taken: Vec<bool>,
    /// Sphere-filter survivors of the current segment (local indices).
    feasible: Vec<usize>,
    /// Flat per-segment winner arena `(score, global key index)`.
    winners: Vec<(f32, usize)>,
    /// Arena offsets: segment `i` owns `winners[seg_off[i]..seg_off[i+1]]`.
    seg_off: Vec<usize>,
    /// Merge cursors, one per live list.
    cursors: Vec<usize>,
}

impl TopkScratch {
    /// Pre-grow every buffer for rows of `s` scores, so the next
    /// `_into` call on such a row allocates nothing.
    pub fn reserve(&mut self, s: usize) {
        reserve_to(&mut self.taken, s);
        reserve_to(&mut self.feasible, s);
        reserve_to(&mut self.winners, s);
        reserve_to(&mut self.seg_off, s + 1);
        reserve_to(&mut self.cursors, s);
    }

    /// Bytes of heap capacity currently held (workspace accounting).
    pub fn capacity_bytes(&self) -> usize {
        self.taken.capacity() * std::mem::size_of::<bool>()
            + self.feasible.capacity() * std::mem::size_of::<usize>()
            + self.winners.capacity() * std::mem::size_of::<(f32, usize)>()
            + self.seg_off.capacity() * std::mem::size_of::<usize>()
            + self.cursors.capacity() * std::mem::size_of::<usize>()
    }
}

/// Grow `v`'s capacity to at least `n` elements (never shrinks).
fn reserve_to<T>(v: &mut Vec<T>, n: usize) {
    if v.capacity() < n {
        v.reserve(n - v.len());
    }
}

/// The extraction-scan core shared by [`vanilla_topk`] and
/// [`merge_topk_candidates`]: `k` passes over `len` candidates, each
/// taking the first strict maximum among the not-yet-taken (score ties
/// resolve to the lowest scan position). Returns the comparison count.
/// Dispatches on the `simd` cargo feature ([`KernelPath::active`]).
fn extract_scan(
    len: usize,
    k: usize,
    score: impl Fn(usize) -> f32,
    taken: &mut Vec<bool>,
    emit: impl FnMut(usize),
) -> u64 {
    extract_scan_with(len, k, score, taken, emit, KernelPath::active())
}

/// [`extract_scan`] with an explicit kernel path, for benches and parity
/// tests.
///
/// The scalar pass keeps a running `(best, best_v)` and updates on every
/// strict improvement. The lane pass instead reduces each 8-wide chunk
/// to its untaken max (taken/absent lanes masked to −∞ — the identity),
/// and only when a chunk's max strictly beats the running best does it
/// rescan that chunk for the first untaken position attaining it.
/// Because `>` is strict and the rescan takes the *first* attaining
/// index, both passes settle on the lowest index attaining the global
/// untaken max — including ±0.0 ties (IEEE `-0.0 == 0.0`, so the rescan
/// equality finds the earlier index regardless of which zero `f32::max`
/// kept) and NaN scores (never `>` anything, masked out of the lane max
/// by `f32::max`). Comparison accounting is one count per untaken
/// element per pass in both spellings, so the exact-`cmp` parity the
/// tests below pin holds on either path.
fn extract_scan_with(
    len: usize,
    k: usize,
    score: impl Fn(usize) -> f32,
    taken: &mut Vec<bool>,
    mut emit: impl FnMut(usize),
    path: KernelPath,
) -> u64 {
    taken.clear();
    taken.resize(len, false);
    let mut cmp_count = 0u64;
    for _ in 0..k {
        let mut best = usize::MAX;
        let mut best_v = f32::NEG_INFINITY;
        match path {
            KernelPath::Scalar => {
                for (j, t) in taken.iter().enumerate() {
                    if !*t {
                        cmp_count += 1;
                        if score(j) > best_v {
                            best_v = score(j);
                            best = j;
                        }
                    }
                }
            }
            KernelPath::Lanes => {
                let mut j0 = 0;
                while j0 < len {
                    let j1 = (j0 + LANES).min(len);
                    let mut lane = [f32::NEG_INFINITY; LANES];
                    let mut untaken = 0u64;
                    for (l, j) in (j0..j1).enumerate() {
                        if !taken[j] {
                            untaken += 1;
                            lane[l] = score(j);
                        }
                    }
                    cmp_count += untaken;
                    let chunk_max = F32x8(lane).hmax(f32::NEG_INFINITY);
                    if chunk_max > best_v {
                        best_v = chunk_max;
                        for j in j0..j1 {
                            if !taken[j] && score(j) == chunk_max {
                                best = j;
                                break;
                            }
                        }
                    }
                    j0 = j1;
                }
            }
        }
        if best == usize::MAX {
            break; // every remaining score is -inf (fully masked input)
        }
        taken[best] = true;
        emit(best);
    }
    cmp_count
}

/// Baseline per-row top-k: repeated max-extraction scans (what "selecting
/// each element requires O(S) operations" describes). Returns indices in
/// descending score order.
pub fn vanilla_topk(row: &[f32], k: usize, c: &mut OpCounter) -> Vec<usize> {
    let mut scratch = TopkScratch::default();
    let mut out = Vec::with_capacity(k.min(row.len()));
    vanilla_topk_into(row, k, c, &mut scratch, &mut out);
    out
}

/// [`vanilla_topk`] writing into a caller-provided buffer (cleared, then
/// filled) using reusable scratch — no allocation once both have the
/// capacity. Selection, order and comparison accounting are identical to
/// the allocating entry point (one shared core).
pub fn vanilla_topk_into(
    row: &[f32],
    k: usize,
    c: &mut OpCounter,
    scratch: &mut TopkScratch,
    out: &mut Vec<usize>,
) {
    vanilla_topk_into_with(row, k, c, scratch, out, KernelPath::active())
}

/// [`vanilla_topk_into`] with an explicit kernel path — the entry point
/// `star bench kernels` and the SIMD parity tests use to compare the
/// scalar and lane extraction scans in one binary (selection, order and
/// comparison counts are identical on both paths; see
/// [`extract_scan_with`]).
pub fn vanilla_topk_into_with(
    row: &[f32],
    k: usize,
    c: &mut OpCounter,
    scratch: &mut TopkScratch,
    out: &mut Vec<usize>,
    path: KernelPath,
) {
    out.clear();
    let cmp = extract_scan_with(
        row.len(),
        k.min(row.len()),
        |j| row[j],
        &mut scratch.taken,
        |j| out.push(j),
        path,
    );
    c.tally(OpKind::Cmp, cmp);
}

/// One sub-segment's output from the distributed phase of SADS:
/// produced by [`sads_segment_winners`], consumed by [`sads_merge`].
#[derive(Clone, Debug)]
pub struct SegmentWinners {
    /// Global sub-segment index (merge order is ascending `seg`).
    pub seg: usize,
    /// Per-segment winners `(score, global key index)`, descending.
    pub winners: Vec<(f32, usize)>,
    /// Elements surviving the sphere filter (the ρ numerator; the
    /// denominator is the caller's global `s`).
    pub survivors: usize,
    /// Comparisons this segment spent (also tallied into the counter).
    pub comparisons: u64,
}

/// The per-segment core: local max, sphere filter at `radius`, then up
/// to `per_seg` selection passes over the survivors, emitted in
/// descending order as `(score, base + local index)`. Shared by every
/// SADS spelling in this module, so their comparisons can never drift.
/// Returns (survivors, comparisons).
fn segment_pass(
    scores: &[f32],
    base: usize,
    per_seg: usize,
    radius: f32,
    feasible: &mut Vec<usize>,
    taken: &mut Vec<bool>,
    mut emit: impl FnMut(f32, usize),
) -> (usize, u64) {
    let len = scores.len();
    assert!(len > 0, "empty SADS segment");
    let mut cmp_count = 0u64;

    // 1) Local max: len − 1 comparisons.
    let mut mx = f32::NEG_INFINITY;
    for &x in scores {
        if x > mx {
            mx = x;
        }
    }
    cmp_count += (len - 1) as u64;

    // 2) Sphere filter: one comparison per element against (max − r).
    let floor = mx - radius;
    feasible.clear();
    feasible.extend((0..len).filter(|&j| scores[j] >= floor));
    cmp_count += len as u64;
    let survivors = feasible.len();

    // 3) Selection passes restricted to the feasible region.
    let take = per_seg.min(feasible.len());
    cmp_count += extract_scan(feasible.len(), take, |fi| scores[feasible[fi]], taken, |fi| {
        emit(scores[feasible[fi]], base + feasible[fi])
    });
    (survivors, cmp_count)
}

/// The per-segment phase of SADS over one sub-segment's score slice:
/// local max, sphere filter at `radius`, then up to `per_seg` selection
/// passes over the survivors. `scores` is the segment's slice and `base`
/// the global index of `scores[0]`, so winners carry global key indices
/// — which is what lets a shard owning this key range run the phase
/// locally, bit-identically to the single-core [`sads_topk`].
pub fn sads_segment_winners(
    scores: &[f32],
    base: usize,
    seg: usize,
    per_seg: usize,
    radius: f32,
    c: &mut OpCounter,
) -> SegmentWinners {
    let mut scratch = TopkScratch::default();
    sads_segment_winners_scratch(scores, base, seg, per_seg, radius, c, &mut scratch)
}

/// [`sads_segment_winners`] with caller-provided scratch (the winner
/// list itself is freshly allocated — it travels in the sharded
/// pipeline's ring payload, so it must own its storage).
pub fn sads_segment_winners_scratch(
    scores: &[f32],
    base: usize,
    seg: usize,
    per_seg: usize,
    radius: f32,
    c: &mut OpCounter,
    scratch: &mut TopkScratch,
) -> SegmentWinners {
    let mut winners = Vec::with_capacity(per_seg.min(scores.len()));
    let (survivors, comparisons) = segment_pass(
        scores,
        base,
        per_seg,
        radius,
        &mut scratch.feasible,
        &mut scratch.taken,
        |v, j| winners.push((v, j)),
    );
    c.tally(OpKind::Cmp, comparisons);
    SegmentWinners { seg, winners, survivors, comparisons }
}

/// The n-way merge core: descending per-list candidates merge into one
/// global descending order, one comparison per output per live list,
/// ties to the earlier list. `peek(li, cursor)` returns list `li`'s
/// candidate at `cursor` (None when exhausted). Shared by every merge
/// spelling in this module. Returns the comparison count.
fn merge_pass(
    nlists: usize,
    peek: impl Fn(usize, usize) -> Option<(f32, usize)>,
    k: usize,
    cursors: &mut Vec<usize>,
    mut emit: impl FnMut(usize),
) -> u64 {
    cursors.clear();
    cursors.resize(nlists, 0);
    let mut cmp_count = 0u64;
    let mut emitted = 0usize;
    while emitted < k {
        let mut best_list = usize::MAX;
        let mut best_v = f32::NEG_INFINITY;
        for (li, &cur) in cursors.iter().enumerate() {
            if let Some((v, _)) = peek(li, cur) {
                cmp_count += 1;
                if v > best_v {
                    best_v = v;
                    best_list = li;
                }
            }
        }
        if best_list == usize::MAX {
            break; // all lists exhausted (aggressive pruning)
        }
        let (_, idx) = peek(best_list, cursors[best_list]).expect("peeked candidate");
        emit(idx);
        cursors[best_list] += 1;
        emitted += 1;
    }
    cmp_count
}

/// The merge phase of SADS: n-way merge of per-segment descending winner
/// lists (ascending `seg` order) into one global descending order — the
/// order SU-FA consumes — truncated to `k`. One comparison per output
/// per live list; ties resolve to the earlier segment, which depends
/// only on the global segment order, never on how segments were
/// distributed across workers. Returns (indices, comparisons).
pub fn sads_merge(lists: &[SegmentWinners], k: usize, c: &mut OpCounter) -> (Vec<usize>, u64) {
    let mut cursors = Vec::with_capacity(lists.len());
    let mut out = Vec::with_capacity(k);
    let cmp = sads_merge_into(lists, k, c, &mut cursors, &mut out);
    (out, cmp)
}

/// [`sads_merge`] writing into caller-provided buffers (cleared, then
/// filled — no allocation once they have the capacity). Returns the
/// comparison count (also tallied into `c`).
pub fn sads_merge_into(
    lists: &[SegmentWinners],
    k: usize,
    c: &mut OpCounter,
    cursors: &mut Vec<usize>,
    out: &mut Vec<usize>,
) -> u64 {
    debug_assert!(lists.windows(2).all(|w| w[0].seg < w[1].seg), "merge wants ascending segments");
    out.clear();
    let cmp = merge_pass(
        lists.len(),
        |li, cur| lists[li].winners.get(cur).copied(),
        k,
        cursors,
        |idx| out.push(idx),
    );
    c.tally(OpKind::Cmp, cmp);
    cmp
}

/// The SADS sub-segment geometry for a row of `s` scores: (segment
/// count, segment length). Shared by [`sads_topk`] and the sharded
/// pipeline's key partitioner so both always agree on boundaries.
pub fn sads_geometry(s: usize, p: &SadsParams) -> (usize, usize) {
    if s == 0 {
        return (0, 0);
    }
    let n = p.segments.max(1).min(s);
    let seg_len = s.div_ceil(n);
    // Trailing segments can be empty when seg_len rounds up past s.
    (s.div_ceil(seg_len), seg_len)
}

/// SADS: distributed per-segment selection with sphere-radius early
/// termination. Returns (indices in descending estimated-score order,
/// stats). Each segment contributes ⌈k/n⌉ winners (clipped to its size);
/// the result is truncated to `k`. Composes the same segment and merge
/// cores the sharded pipeline runs on different workers
/// ([`sads_segment_winners`] / [`sads_merge`]).
pub fn sads_topk(
    row: &[f32],
    k: usize,
    p: &SadsParams,
    c: &mut OpCounter,
) -> (Vec<usize>, SadsStats) {
    let mut scratch = TopkScratch::default();
    let mut out = Vec::with_capacity(k.min(row.len()));
    let stats = sads_topk_into(row, k, p, c, &mut scratch, &mut out);
    (out, stats)
}

/// [`sads_topk`] writing into a caller-provided buffer using reusable
/// [`TopkScratch`] (per-segment winners land in the scratch arena, not
/// per-segment allocations) — no allocation once the buffers have the
/// capacity. Selection, order and comparison accounting are identical to
/// the allocating entry point, which wraps this one.
pub fn sads_topk_into(
    row: &[f32],
    k: usize,
    p: &SadsParams,
    c: &mut OpCounter,
    scratch: &mut TopkScratch,
    out: &mut Vec<usize>,
) -> SadsStats {
    out.clear();
    let s = row.len();
    let k = k.min(s);
    if k == 0 || s == 0 {
        return SadsStats::default();
    }
    let n = p.segments.max(1).min(s);
    let (nseg, seg_len) = sads_geometry(s, p);
    let per_seg = k.div_ceil(n);

    // Split borrows: the segment loop fills `winners`/`seg_off` while the
    // merge reads them with `cursors` advancing — all disjoint fields.
    let TopkScratch { taken, feasible, winners, seg_off, cursors } = scratch;
    winners.clear();
    seg_off.clear();
    let mut survivors_total = 0usize;
    let mut cmp_count = 0u64;
    for seg in 0..nseg {
        let lo = seg * seg_len;
        let hi = (lo + seg_len).min(s);
        seg_off.push(winners.len());
        let (survivors, cmp) =
            segment_pass(&row[lo..hi], lo, per_seg, p.radius, feasible, taken, |v, j| {
                winners.push((v, j))
            });
        survivors_total += survivors;
        cmp_count += cmp;
    }
    seg_off.push(winners.len());
    c.tally(OpKind::Cmp, cmp_count);

    let merge_cmp = merge_pass(
        nseg,
        |li, cur| {
            let (lo, hi) = (seg_off[li], seg_off[li + 1]);
            if lo + cur < hi {
                Some(winners[lo + cur])
            } else {
                None
            }
        },
        k,
        cursors,
        |idx| out.push(idx),
    );
    c.tally(OpKind::Cmp, merge_cmp);
    cmp_count += merge_cmp;

    SadsStats { rho: survivors_total as f64 / s as f64, comparisons: cmp_count }
}

/// The merge pass of the *exact* distributed top-k: select the global
/// top-`k` from per-shard candidate lists. `cands` are `(score, global
/// key index)` pairs and **must be sorted by ascending key index**, so
/// the scan's first-strict-maximum rule resolves score ties to the
/// lowest index — exactly how [`vanilla_topk`] over the full row
/// breaks them. When every shard proposes its local top-`min(k, len)`,
/// the result (set *and* order) equals `vanilla_topk` on the
/// concatenated row: any global winner is necessarily within its own
/// shard's local top-`k`. Returns indices in descending score order.
pub fn merge_topk_candidates(cands: &[(f32, usize)], k: usize, c: &mut OpCounter) -> Vec<usize> {
    let mut scratch = TopkScratch::default();
    let mut out = Vec::with_capacity(k.min(cands.len()));
    merge_topk_candidates_into(cands, k, c, &mut scratch, &mut out);
    out
}

/// [`merge_topk_candidates`] writing into a caller-provided buffer using
/// reusable scratch — same extraction core, identical output and
/// comparison counts.
pub fn merge_topk_candidates_into(
    cands: &[(f32, usize)],
    k: usize,
    c: &mut OpCounter,
    scratch: &mut TopkScratch,
    out: &mut Vec<usize>,
) {
    debug_assert!(cands.windows(2).all(|w| w[0].1 < w[1].1), "candidates must ascend by index");
    out.clear();
    let cmp =
        extract_scan(cands.len(), k.min(cands.len()), |ci| cands[ci].0, &mut scratch.taken, |ci| {
            out.push(cands[ci].1)
        });
    c.tally(OpKind::Cmp, cmp);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::topk_indices;
    use crate::util::Rng;

    fn rand_row(s: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..s).map(|_| rng.normal_f32(0.0, 2.0)).collect()
    }

    #[test]
    fn vanilla_matches_oracle() {
        let row = rand_row(200, 1);
        let mut c = OpCounter::new();
        let got = vanilla_topk(&row, 20, &mut c);
        assert_eq!(got, topk_indices(&row, 20));
        // Comparison count ≈ k·S (minus the extracted ones).
        assert!(c.cmp as usize >= 20 * (200 - 20));
    }

    #[test]
    fn sads_descending_order() {
        let row = rand_row(256, 2);
        let mut c = OpCounter::new();
        let (sel, _) = sads_topk(&row, 32, &SadsParams::default(), &mut c);
        for w in sel.windows(2) {
            assert!(row[w[0]] >= row[w[1]], "not descending");
        }
        assert_eq!(sel.len(), 32);
        let mut uniq = sel.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 32, "duplicates in selection");
    }

    #[test]
    fn sads_recall_high_on_dispersed_rows() {
        // Type-II-like rows (dispersed maxima) are SADS's design target.
        let mut total_hits = 0usize;
        let mut total = 0usize;
        for seed in 0..10u64 {
            let row = rand_row(512, 100 + seed);
            let k = 64;
            let truth = topk_indices(&row, k);
            let mut c = OpCounter::new();
            let (sel, _) = sads_topk(&row, k, &SadsParams::default(), &mut c);
            total_hits += truth.iter().filter(|t| sel.contains(t)).count();
            total += k;
        }
        let recall = total_hits as f64 / total as f64;
        assert!(recall > 0.85, "sads recall {recall}");
    }

    #[test]
    fn sads_far_fewer_comparisons_than_vanilla() {
        let row = rand_row(1024, 3);
        let k = 256; // k-ratio 0.25, the paper's complexity example
        let mut cv = OpCounter::new();
        vanilla_topk(&row, k, &mut cv);
        let mut cs = OpCounter::new();
        let (_, stats) = sads_topk(&row, k, &SadsParams::default(), &mut cs);
        let ratio = cs.cmp as f64 / cv.cmp as f64;
        // Paper: ~10% of standard sorting for S=1024, n=4, k=0.25, ρ≈0.4.
        assert!(ratio < 0.35, "sads/vanilla cmp ratio {ratio} (rho={})", stats.rho);
    }

    #[test]
    fn radius_controls_rho() {
        let row = rand_row(512, 4);
        let mut c = OpCounter::new();
        let (_, tight) = sads_topk(&row, 64, &SadsParams { segments: 4, radius: 1.0 }, &mut c);
        let (_, loose) = sads_topk(&row, 64, &SadsParams { segments: 4, radius: 20.0 }, &mut c);
        assert!(tight.rho < loose.rho);
        assert!((loose.rho - 1.0).abs() < 1e-9, "radius 20σ keeps everything");
    }

    #[test]
    fn more_segments_fewer_comparisons() {
        let row = rand_row(1024, 5);
        let mut cmp_for = |n: usize| {
            let mut c = OpCounter::new();
            sads_topk(&row, 128, &SadsParams { segments: n, radius: 5.0 }, &mut c);
            c.cmp
        };
        let c2 = cmp_for(2);
        let c8 = cmp_for(8);
        assert!(c8 < c2, "n=8 ({c8}) !< n=2 ({c2})");
    }

    #[test]
    fn aggressive_radius_may_underfill_but_never_panics() {
        // A row with one huge spike: radius filters everything else.
        let mut row = vec![0.0f32; 128];
        row[7] = 100.0;
        let mut c = OpCounter::new();
        let (sel, stats) = sads_topk(&row, 32, &SadsParams { segments: 4, radius: 5.0 }, &mut c);
        assert!(sel.contains(&7));
        // Segments without the spike keep elements within r of their own
        // local max (all zeros → all survive), so underfill need not occur;
        // the spike's own segment prunes hard.
        assert!(stats.rho <= 1.0);
    }

    #[test]
    fn fully_masked_scores_select_nothing_instead_of_panicking() {
        // -inf everywhere (fully masked rows): no element can win a
        // strict comparison, so every selection pass must stop cleanly.
        let mut c = OpCounter::new();
        let row = [f32::NEG_INFINITY; 8];
        assert!(vanilla_topk(&row, 4, &mut c).is_empty());
        let l = sads_segment_winners(&row, 0, 0, 2, 1.0, &mut c);
        assert!(l.winners.is_empty());
        assert_eq!(l.survivors, 8, "-inf >= -inf: the sphere filter keeps them");
        let cands: Vec<(f32, usize)> = (0..4).map(|j| (f32::NEG_INFINITY, j)).collect();
        assert!(merge_topk_candidates(&cands, 2, &mut c).is_empty());
        let (sel, _) = sads_topk(&[f32::NEG_INFINITY; 16], 4, &SadsParams::default(), &mut c);
        assert!(sel.is_empty());
    }

    #[test]
    fn edge_cases() {
        let mut c = OpCounter::new();
        assert!(sads_topk(&[], 4, &SadsParams::default(), &mut c).0.is_empty());
        let (one, _) = sads_topk(&[1.0], 4, &SadsParams::default(), &mut c);
        assert_eq!(one, vec![0]);
        let row = rand_row(16, 6);
        let (all, _) = sads_topk(&row, 16, &SadsParams { segments: 4, radius: 1e9 }, &mut c);
        assert_eq!(all.len(), 16);
    }

    #[test]
    fn into_variants_reuse_dirty_buffers_bit_identically() {
        // The arena entry points must equal the allocating ones —
        // selection, order, stats AND comparison accounting — when fed
        // dirty scratch left over from a *different* row, including ties
        // and -inf rows. This is the workspace-reuse contract.
        let mut scratch = TopkScratch::default();
        let mut out = Vec::new();
        let mut cursors = Vec::new();
        for (s, k, seed) in [(256usize, 32usize, 71u64), (130, 20, 72), (7, 7, 73)] {
            let mut row = rand_row(s, seed);
            row[s / 2] = row[s / 3]; // plant a tie
            if seed == 73 {
                row.iter_mut().for_each(|x| *x = f32::NEG_INFINITY);
            }
            for kk in [k, 0, s + 5] {
                // SADS.
                let p = SadsParams::default();
                let mut cw = OpCounter::new();
                let (want, want_stats) = sads_topk(&row, kk, &p, &mut cw);
                let mut cg = OpCounter::new();
                let got_stats = sads_topk_into(&row, kk, &p, &mut cg, &mut scratch, &mut out);
                assert_eq!(out, want, "sads s={s} k={kk}");
                assert_eq!(cg.cmp, cw.cmp, "sads cmp s={s} k={kk}");
                assert_eq!(got_stats.rho, want_stats.rho);
                assert_eq!(got_stats.comparisons, want_stats.comparisons);
                // Vanilla.
                let mut cw = OpCounter::new();
                let want = vanilla_topk(&row, kk, &mut cw);
                let mut cg = OpCounter::new();
                vanilla_topk_into(&row, kk, &mut cg, &mut scratch, &mut out);
                assert_eq!(out, want, "vanilla s={s} k={kk}");
                assert_eq!(cg.cmp, cw.cmp, "vanilla cmp s={s} k={kk}");
                // Candidate merge (ascending-index candidate list).
                let cands: Vec<(f32, usize)> =
                    row.iter().copied().zip(0..).map(|(v, j)| (v, j)).collect();
                let mut cw = OpCounter::new();
                let want = merge_topk_candidates(&cands, kk, &mut cw);
                let mut cg = OpCounter::new();
                merge_topk_candidates_into(&cands, kk, &mut cg, &mut scratch, &mut out);
                assert_eq!(out, want, "cand merge s={s} k={kk}");
                assert_eq!(cg.cmp, cw.cmp);
                // Segment-list merge.
                let n = p.segments.max(1).min(s);
                let (nseg, seg_len) = sads_geometry(s, &p);
                let per_seg = kk.min(s).div_ceil(n.max(1)).max(1);
                let mut cd = OpCounter::new();
                let lists: Vec<SegmentWinners> = (0..nseg)
                    .map(|seg| {
                        let lo = seg * seg_len;
                        let hi = (lo + seg_len).min(s);
                        sads_segment_winners(&row[lo..hi], lo, seg, per_seg, p.radius, &mut cd)
                    })
                    .collect();
                let mut cw = OpCounter::new();
                let (want, _) = sads_merge(&lists, kk.min(s), &mut cw);
                let mut cg = OpCounter::new();
                sads_merge_into(&lists, kk.min(s), &mut cg, &mut cursors, &mut out);
                assert_eq!(out, want, "seg merge s={s} k={kk}");
                assert_eq!(cg.cmp, cw.cmp);
            }
        }
    }

    #[test]
    fn scratch_reserve_makes_selection_capacity_stable() {
        let mut scratch = TopkScratch::default();
        scratch.reserve(512);
        assert!(scratch.capacity_bytes() > 0);
        let row = rand_row(512, 81);
        let mut out = Vec::with_capacity(512);
        let mut c = OpCounter::new();
        sads_topk_into(&row, 128, &SadsParams::default(), &mut c, &mut scratch, &mut out);
        let caps = (
            scratch.taken.capacity(),
            scratch.feasible.capacity(),
            scratch.winners.capacity(),
            scratch.seg_off.capacity(),
            scratch.cursors.capacity(),
        );
        // A second pass over the same shape must not grow anything.
        sads_topk_into(&row, 128, &SadsParams::default(), &mut c, &mut scratch, &mut out);
        vanilla_topk_into(&row, 128, &mut c, &mut scratch, &mut out);
        assert_eq!(
            caps,
            (
                scratch.taken.capacity(),
                scratch.feasible.capacity(),
                scratch.winners.capacity(),
                scratch.seg_off.capacity(),
                scratch.cursors.capacity(),
            ),
            "steady-state selection must not grow scratch"
        );
    }

    #[test]
    fn lanes_extraction_is_bit_identical_to_scalar() {
        // Adversarial rows: cross-chunk ties, ±0.0 ties, -inf floods, NaN
        // scores, and lengths straddling the 8-lane chunk boundary. The
        // lane pass must reproduce selection, order AND comparison counts.
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for (s, seed) in [(7usize, 41u64), (8, 42), (9, 43), (64, 44), (130, 45)] {
            let mut row = rand_row(s, seed);
            if s >= 9 {
                row[1] = row[8]; // tie across chunk boundary
                row[2] = f32::NEG_INFINITY;
                row[3] = -0.0;
                row[4] = 0.0;
            }
            rows.push(row);
        }
        rows.push(vec![f32::NEG_INFINITY; 20]); // fully masked: early break
        let mut nan_row = rand_row(16, 46);
        nan_row[5] = f32::NAN;
        nan_row[12] = f32::NAN;
        rows.push(nan_row); // NaN never selected on either path
        for row in &rows {
            for k in [1usize, 3, 8, row.len(), row.len() + 5] {
                let mut ss = TopkScratch::default();
                let mut sl = TopkScratch::default();
                let (mut os, mut ol) = (Vec::new(), Vec::new());
                let mut cs = OpCounter::new();
                let mut cl = OpCounter::new();
                vanilla_topk_into_with(row, k, &mut cs, &mut ss, &mut os, KernelPath::Scalar);
                vanilla_topk_into_with(row, k, &mut cl, &mut sl, &mut ol, KernelPath::Lanes);
                assert_eq!(os, ol, "len={} k={k} selection drift", row.len());
                assert_eq!(cs.cmp, cl.cmp, "len={} k={k} cmp drift", row.len());
            }
        }
    }

    #[test]
    fn distributed_sads_phases_equal_whole_row_sads() {
        // The sharded pipeline's contract: running the segment phase on
        // per-worker score slices and merging the lists afterwards must
        // reproduce sads_topk on the whole row — selection, order, AND
        // comparison counts, for divisible and non-divisible lengths.
        for (s, k, seed) in [(256usize, 32usize, 21u64), (130, 20, 22), (257, 64, 23)] {
            let row = rand_row(s, seed);
            let p = SadsParams::default();
            let mut cw = OpCounter::new();
            let (want, stats) = sads_topk(&row, k, &p, &mut cw);

            let n = p.segments.max(1).min(s);
            let (nseg, seg_len) = sads_geometry(s, &p);
            let per_seg = k.div_ceil(n);
            let mut cd = OpCounter::new();
            // "Workers": segments computed in scrambled order from slices.
            let mut lists: Vec<SegmentWinners> = (0..nseg)
                .rev()
                .map(|seg| {
                    let lo = seg * seg_len;
                    let hi = (lo + seg_len).min(s);
                    sads_segment_winners(&row[lo..hi], lo, seg, per_seg, p.radius, &mut cd)
                })
                .collect();
            lists.sort_by_key(|l| l.seg);
            let (got, _) = sads_merge(&lists, k.min(s), &mut cd);
            assert_eq!(got, want, "s={s} k={k}: distributed selection drift");
            assert_eq!(cd.cmp, cw.cmp, "s={s} k={k}: comparison accounting drift");
            let survivors: usize = lists.iter().map(|l| l.survivors).sum();
            assert!((survivors as f64 / s as f64 - stats.rho).abs() < 1e-12);
        }
    }

    #[test]
    fn candidate_merge_equals_whole_row_vanilla() {
        // Exact engine: per-shard local top-k proposals + merge must equal
        // vanilla_topk on the full row, including tie order.
        for (s, k, shards, seed) in [(96usize, 24usize, 3usize, 31u64), (101, 17, 4, 32)] {
            let mut row = rand_row(s, seed);
            row[5] = row[40]; // plant a cross-shard tie
            let mut cw = OpCounter::new();
            let want = vanilla_topk(&row, k, &mut cw);
            let mut cd = OpCounter::new();
            let mut cands: Vec<(f32, usize)> = Vec::new();
            for w in 0..shards {
                let (lo, hi) = (w * s / shards, (w + 1) * s / shards);
                let local = vanilla_topk(&row[lo..hi], k.min(hi - lo), &mut cd);
                let mut local: Vec<(f32, usize)> =
                    local.into_iter().map(|j| (row[lo + j], lo + j)).collect();
                local.sort_by_key(|&(_, j)| j);
                cands.extend(local);
            }
            let got = merge_topk_candidates(&cands, k, &mut cd);
            assert_eq!(got, want, "s={s} k={k} shards={shards}");
        }
    }
}
