//! The *top-k* stage: selecting the vital Q–K pairs from the estimated Â.
//!
//! * [`vanilla_topk`] — the baseline most DS accelerators use: per-row
//!   selection where extracting each of the `S·k` winners scans the whole
//!   remaining row — O(S·S·k) comparisons per row (Sec. III-A(1)).
//! * [`sads_topk`] — Sphere-search Aided Distributed Sorting (Sec. IV-B):
//!   the row splits into `n` sub-segments; each finds its local max
//!   (`len−1` comparisons), eliminates every element with `Δ = max − x > r`
//!   (one comparison each — justified by Eq. 5: softmax(x) < e^−Δ), and
//!   runs the selection passes only over the surviving ρ fraction:
//!   O(S·S·k·ρ/n) total. Survivor lists merge into one descending order for
//!   SU-FA.
//!
//! SADS is *distributed by construction*: the per-segment pass
//! ([`sads_segment_winners`]) reads only its own segment's scores, and
//! the merge ([`sads_merge`]) reads only the per-segment winner lists.
//! [`sads_topk`] composes the two on one core; the sequence-sharded
//! pipeline ([`crate::pipeline::ShardedPipeline`]) runs the segment
//! passes on the workers owning those key ranges and the merge at the
//! query block's home worker — same functions, same comparisons, same
//! selection, bit for bit. [`merge_topk_candidates`] is the analogous
//! merge pass for the exact (vanilla) engine.

use crate::arith::{OpCounter, OpKind};

/// SADS configuration.
#[derive(Clone, Copy, Debug)]
pub struct SadsParams {
    /// Number of sub-segments n.
    pub segments: usize,
    /// Sphere radius r (score units); elements with max − x > r are pruned.
    pub radius: f32,
}

impl Default for SadsParams {
    fn default() -> Self {
        SadsParams { segments: 4, radius: 5.0 }
    }
}

/// Statistics from one SADS row pass.
#[derive(Clone, Copy, Debug, Default)]
pub struct SadsStats {
    /// Fraction of elements surviving the sphere filter (ρ).
    pub rho: f64,
    /// Comparisons spent (same number tallied into the OpCounter).
    pub comparisons: u64,
}

/// Baseline per-row top-k: repeated max-extraction scans (what "selecting
/// each element requires O(S) operations" describes). Returns indices in
/// descending score order.
pub fn vanilla_topk(row: &[f32], k: usize, c: &mut OpCounter) -> Vec<usize> {
    let s = row.len();
    let k = k.min(s);
    let mut taken = vec![false; s];
    let mut out = Vec::with_capacity(k);
    for _ in 0..k {
        let mut best = usize::MAX;
        let mut best_v = f32::NEG_INFINITY;
        for (j, &x) in row.iter().enumerate() {
            if !taken[j] {
                c.tally(OpKind::Cmp, 1);
                if x > best_v {
                    best_v = x;
                    best = j;
                }
            }
        }
        if best == usize::MAX {
            break; // every remaining score is -inf (fully masked row)
        }
        taken[best] = true;
        out.push(best);
    }
    out
}

/// One sub-segment's output from the distributed phase of SADS:
/// produced by [`sads_segment_winners`], consumed by [`sads_merge`].
#[derive(Clone, Debug)]
pub struct SegmentWinners {
    /// Global sub-segment index (merge order is ascending `seg`).
    pub seg: usize,
    /// Per-segment winners `(score, global key index)`, descending.
    pub winners: Vec<(f32, usize)>,
    /// Elements surviving the sphere filter (the ρ numerator; the
    /// denominator is the caller's global `s`).
    pub survivors: usize,
    /// Comparisons this segment spent (also tallied into the counter).
    pub comparisons: u64,
}

/// The per-segment phase of SADS over one sub-segment's score slice:
/// local max, sphere filter at `radius`, then up to `per_seg` selection
/// passes over the survivors. `scores` is the segment's slice and `base`
/// the global index of `scores[0]`, so winners carry global key indices
/// — which is what lets a shard owning this key range run the phase
/// locally, bit-identically to the single-core [`sads_topk`].
pub fn sads_segment_winners(
    scores: &[f32],
    base: usize,
    seg: usize,
    per_seg: usize,
    radius: f32,
    c: &mut OpCounter,
) -> SegmentWinners {
    let len = scores.len();
    assert!(len > 0, "empty SADS segment");
    let mut cmp_count = 0u64;

    // 1) Local max: len − 1 comparisons.
    let mut mx = f32::NEG_INFINITY;
    for &x in scores {
        if x > mx {
            mx = x;
        }
    }
    cmp_count += (len - 1) as u64;

    // 2) Sphere filter: one comparison per element against (max − r).
    let floor = mx - radius;
    let feasible: Vec<usize> = (0..len).filter(|&j| scores[j] >= floor).collect();
    cmp_count += len as u64;
    let survivors = feasible.len();

    // 3) Selection passes restricted to the feasible region.
    let take = per_seg.min(feasible.len());
    let mut taken = vec![false; feasible.len()];
    let mut winners = Vec::with_capacity(take);
    for _ in 0..take {
        let mut bi = usize::MAX;
        let mut bv = f32::NEG_INFINITY;
        for (fi, &j) in feasible.iter().enumerate() {
            if !taken[fi] {
                cmp_count += 1;
                if scores[j] > bv {
                    bv = scores[j];
                    bi = fi;
                }
            }
        }
        if bi == usize::MAX {
            break; // every survivor is -inf (fully masked segment)
        }
        taken[bi] = true;
        winners.push((scores[feasible[bi]], base + feasible[bi]));
    }

    c.tally(OpKind::Cmp, cmp_count);
    SegmentWinners { seg, winners, survivors, comparisons: cmp_count }
}

/// The merge phase of SADS: n-way merge of per-segment descending winner
/// lists (ascending `seg` order) into one global descending order — the
/// order SU-FA consumes — truncated to `k`. One comparison per output
/// per live list; ties resolve to the earlier segment, which depends
/// only on the global segment order, never on how segments were
/// distributed across workers. Returns (indices, comparisons).
pub fn sads_merge(lists: &[SegmentWinners], k: usize, c: &mut OpCounter) -> (Vec<usize>, u64) {
    debug_assert!(lists.windows(2).all(|w| w[0].seg < w[1].seg), "merge wants ascending segments");
    let mut cmp_count = 0u64;
    let mut cursors = vec![0usize; lists.len()];
    let mut merged: Vec<usize> = Vec::with_capacity(k);
    while merged.len() < k {
        let mut best_list = usize::MAX;
        let mut best_v = f32::NEG_INFINITY;
        for (li, list) in lists.iter().enumerate() {
            if cursors[li] < list.winners.len() {
                cmp_count += 1;
                if list.winners[cursors[li]].0 > best_v {
                    best_v = list.winners[cursors[li]].0;
                    best_list = li;
                }
            }
        }
        if best_list == usize::MAX {
            break; // all lists exhausted (aggressive pruning)
        }
        merged.push(lists[best_list].winners[cursors[best_list]].1);
        cursors[best_list] += 1;
    }
    c.tally(OpKind::Cmp, cmp_count);
    (merged, cmp_count)
}

/// The SADS sub-segment geometry for a row of `s` scores: (segment
/// count, segment length). Shared by [`sads_topk`] and the sharded
/// pipeline's key partitioner so both always agree on boundaries.
pub fn sads_geometry(s: usize, p: &SadsParams) -> (usize, usize) {
    if s == 0 {
        return (0, 0);
    }
    let n = p.segments.max(1).min(s);
    let seg_len = s.div_ceil(n);
    // Trailing segments can be empty when seg_len rounds up past s.
    (s.div_ceil(seg_len), seg_len)
}

/// SADS: distributed per-segment selection with sphere-radius early
/// termination. Returns (indices in descending estimated-score order,
/// stats). Each segment contributes ⌈k/n⌉ winners (clipped to its size);
/// the result is truncated to `k`. Composes [`sads_segment_winners`] and
/// [`sads_merge`] — the sharded pipeline runs the same two phases on
/// different workers.
pub fn sads_topk(
    row: &[f32],
    k: usize,
    p: &SadsParams,
    c: &mut OpCounter,
) -> (Vec<usize>, SadsStats) {
    let s = row.len();
    let k = k.min(s);
    if k == 0 || s == 0 {
        return (Vec::new(), SadsStats::default());
    }
    let n = p.segments.max(1).min(s);
    let (nseg, seg_len) = sads_geometry(s, p);
    let per_seg = k.div_ceil(n);

    let mut seg_lists: Vec<SegmentWinners> = Vec::with_capacity(nseg);
    for seg in 0..nseg {
        let lo = seg * seg_len;
        let hi = (lo + seg_len).min(s);
        seg_lists.push(sads_segment_winners(&row[lo..hi], lo, seg, per_seg, p.radius, c));
    }

    let survivors_total: usize = seg_lists.iter().map(|l| l.survivors).sum();
    let mut cmp_count: u64 = seg_lists.iter().map(|l| l.comparisons).sum();
    let (merged, merge_cmp) = sads_merge(&seg_lists, k, c);
    cmp_count += merge_cmp;

    let stats = SadsStats { rho: survivors_total as f64 / s as f64, comparisons: cmp_count };
    (merged, stats)
}

/// The merge pass of the *exact* distributed top-k: select the global
/// top-`k` from per-shard candidate lists. `cands` are `(score, global
/// key index)` pairs and **must be sorted by ascending key index**, so
/// the scan's first-strict-maximum rule resolves score ties to the
/// lowest index — exactly how [`vanilla_topk`] over the full row
/// breaks them. When every shard proposes its local top-`min(k, len)`,
/// the result (set *and* order) equals `vanilla_topk` on the
/// concatenated row: any global winner is necessarily within its own
/// shard's local top-`k`. Returns indices in descending score order.
pub fn merge_topk_candidates(cands: &[(f32, usize)], k: usize, c: &mut OpCounter) -> Vec<usize> {
    debug_assert!(cands.windows(2).all(|w| w[0].1 < w[1].1), "candidates must ascend by index");
    let k = k.min(cands.len());
    let mut cmp_count = 0u64;
    let mut taken = vec![false; cands.len()];
    let mut out = Vec::with_capacity(k);
    for _ in 0..k {
        let mut best = usize::MAX;
        let mut best_v = f32::NEG_INFINITY;
        for (ci, &(v, _)) in cands.iter().enumerate() {
            if !taken[ci] {
                cmp_count += 1;
                if v > best_v {
                    best_v = v;
                    best = ci;
                }
            }
        }
        if best == usize::MAX {
            break; // every remaining candidate is -inf
        }
        taken[best] = true;
        out.push(cands[best].1);
    }
    c.tally(OpKind::Cmp, cmp_count);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::topk_indices;
    use crate::util::Rng;

    fn rand_row(s: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..s).map(|_| rng.normal_f32(0.0, 2.0)).collect()
    }

    #[test]
    fn vanilla_matches_oracle() {
        let row = rand_row(200, 1);
        let mut c = OpCounter::new();
        let got = vanilla_topk(&row, 20, &mut c);
        assert_eq!(got, topk_indices(&row, 20));
        // Comparison count ≈ k·S (minus the extracted ones).
        assert!(c.cmp as usize >= 20 * (200 - 20));
    }

    #[test]
    fn sads_descending_order() {
        let row = rand_row(256, 2);
        let mut c = OpCounter::new();
        let (sel, _) = sads_topk(&row, 32, &SadsParams::default(), &mut c);
        for w in sel.windows(2) {
            assert!(row[w[0]] >= row[w[1]], "not descending");
        }
        assert_eq!(sel.len(), 32);
        let mut uniq = sel.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 32, "duplicates in selection");
    }

    #[test]
    fn sads_recall_high_on_dispersed_rows() {
        // Type-II-like rows (dispersed maxima) are SADS's design target.
        let mut total_hits = 0usize;
        let mut total = 0usize;
        for seed in 0..10u64 {
            let row = rand_row(512, 100 + seed);
            let k = 64;
            let truth = topk_indices(&row, k);
            let mut c = OpCounter::new();
            let (sel, _) = sads_topk(&row, k, &SadsParams::default(), &mut c);
            total_hits += truth.iter().filter(|t| sel.contains(t)).count();
            total += k;
        }
        let recall = total_hits as f64 / total as f64;
        assert!(recall > 0.85, "sads recall {recall}");
    }

    #[test]
    fn sads_far_fewer_comparisons_than_vanilla() {
        let row = rand_row(1024, 3);
        let k = 256; // k-ratio 0.25, the paper's complexity example
        let mut cv = OpCounter::new();
        vanilla_topk(&row, k, &mut cv);
        let mut cs = OpCounter::new();
        let (_, stats) = sads_topk(&row, k, &SadsParams::default(), &mut cs);
        let ratio = cs.cmp as f64 / cv.cmp as f64;
        // Paper: ~10% of standard sorting for S=1024, n=4, k=0.25, ρ≈0.4.
        assert!(ratio < 0.35, "sads/vanilla cmp ratio {ratio} (rho={})", stats.rho);
    }

    #[test]
    fn radius_controls_rho() {
        let row = rand_row(512, 4);
        let mut c = OpCounter::new();
        let (_, tight) = sads_topk(&row, 64, &SadsParams { segments: 4, radius: 1.0 }, &mut c);
        let (_, loose) = sads_topk(&row, 64, &SadsParams { segments: 4, radius: 20.0 }, &mut c);
        assert!(tight.rho < loose.rho);
        assert!((loose.rho - 1.0).abs() < 1e-9, "radius 20σ keeps everything");
    }

    #[test]
    fn more_segments_fewer_comparisons() {
        let row = rand_row(1024, 5);
        let mut cmp_for = |n: usize| {
            let mut c = OpCounter::new();
            sads_topk(&row, 128, &SadsParams { segments: n, radius: 5.0 }, &mut c);
            c.cmp
        };
        let c2 = cmp_for(2);
        let c8 = cmp_for(8);
        assert!(c8 < c2, "n=8 ({c8}) !< n=2 ({c2})");
    }

    #[test]
    fn aggressive_radius_may_underfill_but_never_panics() {
        // A row with one huge spike: radius filters everything else.
        let mut row = vec![0.0f32; 128];
        row[7] = 100.0;
        let mut c = OpCounter::new();
        let (sel, stats) = sads_topk(&row, 32, &SadsParams { segments: 4, radius: 5.0 }, &mut c);
        assert!(sel.contains(&7));
        // Segments without the spike keep elements within r of their own
        // local max (all zeros → all survive), so underfill need not occur;
        // the spike's own segment prunes hard.
        assert!(stats.rho <= 1.0);
    }

    #[test]
    fn fully_masked_scores_select_nothing_instead_of_panicking() {
        // -inf everywhere (fully masked rows): no element can win a
        // strict comparison, so every selection pass must stop cleanly.
        let mut c = OpCounter::new();
        let row = [f32::NEG_INFINITY; 8];
        assert!(vanilla_topk(&row, 4, &mut c).is_empty());
        let l = sads_segment_winners(&row, 0, 0, 2, 1.0, &mut c);
        assert!(l.winners.is_empty());
        assert_eq!(l.survivors, 8, "-inf >= -inf: the sphere filter keeps them");
        let cands: Vec<(f32, usize)> = (0..4).map(|j| (f32::NEG_INFINITY, j)).collect();
        assert!(merge_topk_candidates(&cands, 2, &mut c).is_empty());
        let (sel, _) = sads_topk(&[f32::NEG_INFINITY; 16], 4, &SadsParams::default(), &mut c);
        assert!(sel.is_empty());
    }

    #[test]
    fn edge_cases() {
        let mut c = OpCounter::new();
        assert!(sads_topk(&[], 4, &SadsParams::default(), &mut c).0.is_empty());
        let (one, _) = sads_topk(&[1.0], 4, &SadsParams::default(), &mut c);
        assert_eq!(one, vec![0]);
        let row = rand_row(16, 6);
        let (all, _) = sads_topk(&row, 16, &SadsParams { segments: 4, radius: 1e9 }, &mut c);
        assert_eq!(all.len(), 16);
    }

    #[test]
    fn distributed_sads_phases_equal_whole_row_sads() {
        // The sharded pipeline's contract: running the segment phase on
        // per-worker score slices and merging the lists afterwards must
        // reproduce sads_topk on the whole row — selection, order, AND
        // comparison counts, for divisible and non-divisible lengths.
        for (s, k, seed) in [(256usize, 32usize, 21u64), (130, 20, 22), (257, 64, 23)] {
            let row = rand_row(s, seed);
            let p = SadsParams::default();
            let mut cw = OpCounter::new();
            let (want, stats) = sads_topk(&row, k, &p, &mut cw);

            let n = p.segments.max(1).min(s);
            let (nseg, seg_len) = sads_geometry(s, &p);
            let per_seg = k.div_ceil(n);
            let mut cd = OpCounter::new();
            // "Workers": segments computed in scrambled order from slices.
            let mut lists: Vec<SegmentWinners> = (0..nseg)
                .rev()
                .map(|seg| {
                    let lo = seg * seg_len;
                    let hi = (lo + seg_len).min(s);
                    sads_segment_winners(&row[lo..hi], lo, seg, per_seg, p.radius, &mut cd)
                })
                .collect();
            lists.sort_by_key(|l| l.seg);
            let (got, _) = sads_merge(&lists, k.min(s), &mut cd);
            assert_eq!(got, want, "s={s} k={k}: distributed selection drift");
            assert_eq!(cd.cmp, cw.cmp, "s={s} k={k}: comparison accounting drift");
            let survivors: usize = lists.iter().map(|l| l.survivors).sum();
            assert!((survivors as f64 / s as f64 - stats.rho).abs() < 1e-12);
        }
    }

    #[test]
    fn candidate_merge_equals_whole_row_vanilla() {
        // Exact engine: per-shard local top-k proposals + merge must equal
        // vanilla_topk on the full row, including tie order.
        for (s, k, shards, seed) in [(96usize, 24usize, 3usize, 31u64), (101, 17, 4, 32)] {
            let mut row = rand_row(s, seed);
            row[5] = row[40]; // plant a cross-shard tie
            let mut cw = OpCounter::new();
            let want = vanilla_topk(&row, k, &mut cw);
            let mut cd = OpCounter::new();
            let mut cands: Vec<(f32, usize)> = Vec::new();
            for w in 0..shards {
                let (lo, hi) = (w * s / shards, (w + 1) * s / shards);
                let local = vanilla_topk(&row[lo..hi], k.min(hi - lo), &mut cd);
                let mut local: Vec<(f32, usize)> =
                    local.into_iter().map(|j| (row[lo + j], lo + j)).collect();
                local.sort_by_key(|&(_, j)| j);
                cands.extend(local);
            }
            let got = merge_topk_candidates(&cands, k, &mut cd);
            assert_eq!(got, want, "s={s} k={k} shards={shards}");
        }
    }
}
