//! Design-space exploration over the SADS sub-segment count (Appendix A).
//!
//! The trade-off (end of Sec. IV-C): smaller sub-segments `S_i` (more
//! segments n) cut sorting comparisons but fragment SU-FA's tiles — each
//! segment boundary forces a partial tile, adding exponential-unit work and
//! synchronization — and hurt selection recall. The DSE minimizes
//!
//! `J(n) = α · C_sort(n) + β · C_sufa(n) + λ · (1 − recall(n))`
//!
//! where `C_sort` is measured by running SADS on sample rows, `C_sufa`
//! counts the fragmented-tile exponential work, and recall is measured
//! against the exact top-k. A successive-halving grid search (the paper's
//! strategy) spends few sample rows on obviously-bad candidates and
//! refines the survivors.

use super::topk::{sads_topk, SadsParams};
use crate::arith::{EquivWeights, OpCounter};
use crate::tensor::topk_indices;
use crate::util::ceil_div;

/// DSE objective weights; α/β follow the paper's per-model settings
/// (e.g. 0.4/0.42 for GPT-2).
#[derive(Clone, Copy, Debug)]
pub struct DseWeights {
    pub alpha: f64,
    pub beta: f64,
    /// Recall-loss penalty; large enough that accuracy dominates ties.
    pub lambda: f64,
}

impl Default for DseWeights {
    fn default() -> Self {
        DseWeights { alpha: 0.4, beta: 0.42, lambda: 1e6 }
    }
}

/// One evaluated candidate.
#[derive(Clone, Debug)]
pub struct DseCandidate {
    pub segments: usize,
    pub cost_sort: f64,
    pub cost_sufa: f64,
    pub recall: f64,
    pub objective: f64,
}

/// Result of the exploration.
#[derive(Clone, Debug)]
pub struct DseResult {
    pub best: DseCandidate,
    pub evaluated: Vec<DseCandidate>,
}

/// Evaluate one segment count on a set of sample rows.
fn evaluate(
    rows: &[Vec<f32>],
    k_ratio: f64,
    radius: f32,
    segments: usize,
    sufa_bc: usize,
    w: &DseWeights,
) -> DseCandidate {
    let ew = EquivWeights::default();
    let mut cost_sort = 0.0;
    let mut cost_sufa = 0.0;
    let mut recall_acc = 0.0;
    for row in rows {
        let s = row.len();
        let k = ((s as f64 * k_ratio).round() as usize).clamp(1, s);
        let mut c = OpCounter::new();
        let (sel, _) = sads_topk(row, k, &SadsParams { segments, radius }, &mut c);
        cost_sort += c.equivalent_adds(&ew);

        // SU-FA fragmentation: each segment's winners tile independently
        // (segments sync at their boundaries), so the tile count is
        // n · ⌈(k/n)/B_c⌉ instead of ⌈k/B_c⌉; every extra tile costs one
        // boundary rescale (exp + add) worth of work.
        let per_seg = ceil_div(k, segments);
        let tiles = segments * ceil_div(per_seg, sufa_bc);
        let ideal_tiles = ceil_div(k, sufa_bc);
        cost_sufa += (tiles - ideal_tiles.min(tiles)) as f64 * (ew.exp + ew.add);

        let truth = topk_indices(row, k);
        recall_acc += super::hitrate::hit_rate(&sel, &truth);
    }
    let n = rows.len().max(1) as f64;
    let (cost_sort, cost_sufa, recall) = (cost_sort / n, cost_sufa / n, recall_acc / n);
    let objective = w.alpha * cost_sort + w.beta * cost_sufa + w.lambda * (1.0 - recall);
    DseCandidate { segments, cost_sort, cost_sufa, recall, objective }
}

/// Successive-halving DSE: start with all candidate segment counts on a
/// small row sample; halve the candidate set on progressively larger
/// samples until one winner remains.
pub fn explore_segments(
    sample_rows: &[Vec<f32>],
    k_ratio: f64,
    radius: f32,
    sufa_bc: usize,
    candidates: &[usize],
    w: &DseWeights,
) -> DseResult {
    assert!(!sample_rows.is_empty() && !candidates.is_empty());
    let mut live: Vec<usize> = candidates.to_vec();
    let mut all: Vec<DseCandidate> = Vec::new();
    let mut budget = (sample_rows.len() / 4).max(1);

    while live.len() > 1 && budget <= sample_rows.len() {
        let rows = &sample_rows[..budget];
        let mut scored: Vec<DseCandidate> = live
            .iter()
            .map(|&n| evaluate(rows, k_ratio, radius, n, sufa_bc, w))
            .collect();
        scored.sort_by(|a, b| a.objective.partial_cmp(&b.objective).unwrap());
        let keep = ceil_div(scored.len(), 2);
        live = scored[..keep].iter().map(|c| c.segments).collect();
        all.extend(scored);
        if budget == sample_rows.len() {
            break;
        }
        budget = (budget * 2).min(sample_rows.len());
    }

    // Final full-sample evaluation of the survivor(s).
    let mut finals: Vec<DseCandidate> = live
        .iter()
        .map(|&n| evaluate(sample_rows, k_ratio, radius, n, sufa_bc, w))
        .collect();
    finals.sort_by(|a, b| a.objective.partial_cmp(&b.objective).unwrap());
    let best = finals[0].clone();
    all.extend(finals);
    DseResult { best, evaluated: all }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn sample(n_rows: usize, s: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n_rows).map(|_| (0..s).map(|_| rng.normal_f32(0.0, 2.0)).collect()).collect()
    }

    #[test]
    fn picks_a_candidate_with_high_recall() {
        let rows = sample(32, 512, 1);
        let r = explore_segments(&rows, 0.2, 5.0, 16, &[1, 2, 4, 8, 16], &DseWeights::default());
        assert!(r.best.recall > 0.85, "best recall {}", r.best.recall);
        assert!([1, 2, 4, 8, 16].contains(&r.best.segments));
    }

    #[test]
    fn more_segments_cheaper_sorting_in_eval() {
        let rows = sample(16, 1024, 2);
        let w = DseWeights::default();
        let c1 = evaluate(&rows, 0.25, 5.0, 1, 16, &w);
        let c8 = evaluate(&rows, 0.25, 5.0, 8, 16, &w);
        assert!(c8.cost_sort < c1.cost_sort);
        // ...but fragments SU-FA more.
        assert!(c8.cost_sufa >= c1.cost_sufa);
    }

    #[test]
    fn lambda_dominates_when_recall_collapses() {
        // With a tiny radius, many segments lose recall; a huge λ must
        // push the DSE towards fewer segments than a λ=0 run would pick.
        let rows = sample(24, 512, 3);
        let strict =
            explore_segments(&rows, 0.2, 0.5, 16, &[1, 4, 16, 64], &DseWeights { lambda: 1e9, ..Default::default() });
        let loose =
            explore_segments(&rows, 0.2, 0.5, 16, &[1, 4, 16, 64], &DseWeights { lambda: 0.0, ..Default::default() });
        assert!(strict.best.recall >= loose.best.recall);
    }

    #[test]
    fn evaluated_log_is_nonempty_and_sorted_runs_exist() {
        let rows = sample(8, 256, 4);
        let r = explore_segments(&rows, 0.2, 5.0, 16, &[2, 4], &DseWeights::default());
        assert!(!r.evaluated.is_empty());
    }
}
