//! The Type I/II/III attention-row taxonomy of Fig. 9.
//!
//! * **Type I** — a few highly dominant tokens (sharp spike; the rest far
//!   below). Common in ViT/GPT/LLaMA (~22%).
//! * **Type II** — large tokens evenly distributed across regions (~73%,
//!   the dominant case; the reason local maxima stand in for global ones).
//! * **Type III** — large tokens concentrated in one region (negligible,
//!   →0 in GPT-2/LLaMA).
//!
//! The classifier mirrors how the paper *uses* the taxonomy: it looks at
//! where the top-k mass sits relative to the sub-segment structure SADS
//! partitions a row into.

/// Distribution type of one attention row.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DistType {
    TypeI,
    TypeII,
    TypeIII,
}

/// Classification parameters.
#[derive(Clone, Copy, Debug)]
pub struct ClassifyParams {
    /// Number of regions the row is partitioned into (matches SADS n).
    pub regions: usize,
    /// Fraction of the row treated as "large" tokens (top-k ratio).
    pub top_fraction: f64,
    /// Softmax-mass share above which the few leaders count as dominant.
    pub dominance_mass: f64,
    /// How many leaders may carry the dominant mass for Type I.
    pub dominant_leaders: usize,
    /// Fraction of large tokens inside one region that makes it Type III.
    pub concentration: f64,
}

impl Default for ClassifyParams {
    fn default() -> Self {
        ClassifyParams {
            regions: 4,
            top_fraction: 0.1,
            dominance_mass: 0.5,
            dominant_leaders: 4,
            concentration: 0.7,
        }
    }
}

/// Classify one attention-score row (pre-softmax logits).
pub fn classify_row(row: &[f32], p: &ClassifyParams) -> DistType {
    let s = row.len();
    assert!(s >= p.regions, "row shorter than region count");
    let k = ((s as f64 * p.top_fraction).ceil() as usize).clamp(1, s);

    // Softmax mass of the leaders (numerically stable).
    let top = crate::tensor::topk_indices(row, k);
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let total: f64 = row.iter().map(|&x| ((x - m) as f64).exp()).sum();
    let leaders = p.dominant_leaders.min(top.len());
    let leader_mass: f64 =
        top[..leaders].iter().map(|&j| ((row[j] - m) as f64).exp()).sum::<f64>() / total;

    // Type I: a handful of tokens carry most of the softmax mass.
    if leader_mass >= p.dominance_mass {
        return DistType::TypeI;
    }

    // Count large tokens per region.
    let region_len = s.div_ceil(p.regions);
    let mut counts = vec![0usize; p.regions];
    for &j in &top {
        counts[(j / region_len).min(p.regions - 1)] += 1;
    }
    let max_region = counts.iter().copied().max().unwrap_or(0);

    // Type III: large tokens pile into one region.
    if max_region as f64 >= p.concentration * k as f64 {
        return DistType::TypeIII;
    }
    DistType::TypeII
}

/// Fractions of each type over a set of rows — the Fig. 9 statistic.
#[derive(Clone, Copy, Debug, Default)]
pub struct TypeMix {
    pub type1: f64,
    pub type2: f64,
    pub type3: f64,
}

impl TypeMix {
    pub fn of(rows: &[Vec<f32>], p: &ClassifyParams) -> TypeMix {
        let mut c = [0usize; 3];
        for r in rows {
            match classify_row(r, p) {
                DistType::TypeI => c[0] += 1,
                DistType::TypeII => c[1] += 1,
                DistType::TypeIII => c[2] += 1,
            }
        }
        let n = rows.len().max(1) as f64;
        TypeMix { type1: c[0] as f64 / n, type2: c[1] as f64 / n, type3: c[2] as f64 / n }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn spike_row_is_type1() {
        let mut row = vec![0.0f32; 128];
        row[10] = 12.0;
        row[90] = 11.0;
        assert_eq!(classify_row(&row, &ClassifyParams::default()), DistType::TypeI);
    }

    #[test]
    fn dispersed_row_is_type2() {
        // Moderately large tokens in every region, none dominant.
        let mut rng = Rng::new(1);
        let mut row: Vec<f32> = (0..128).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        for region in 0..4 {
            for i in 0..4 {
                row[region * 32 + i * 7] = 3.0 + 0.1 * i as f32;
            }
        }
        assert_eq!(classify_row(&row, &ClassifyParams::default()), DistType::TypeII);
    }

    #[test]
    fn concentrated_row_is_type3() {
        let mut rng = Rng::new(2);
        let mut row: Vec<f32> = (0..128).map(|_| rng.normal_f32(0.0, 0.3)).collect();
        // All large tokens in region 2, many of them (so no Type-I spike).
        for i in 0..13 {
            row[64 + i * 2] = 4.0 + 0.05 * i as f32;
        }
        assert_eq!(classify_row(&row, &ClassifyParams::default()), DistType::TypeIII);
    }

    #[test]
    fn mix_sums_to_one() {
        let mut rng = Rng::new(3);
        let rows: Vec<Vec<f32>> =
            (0..50).map(|_| (0..64).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();
        let mix = TypeMix::of(&rows, &ClassifyParams::default());
        assert!((mix.type1 + mix.type2 + mix.type3 - 1.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "row shorter")]
    fn too_short_rows_rejected() {
        classify_row(&[1.0, 2.0], &ClassifyParams::default());
    }
}
