//! The dynamic-sparsity pipeline: *pre-compute* (prediction) and *top-k*
//! stages, plus the analyses built on them.
//!
//! * [`predictor`] — cross-phase DLZS prediction (Sec. IV-A): estimate K
//!   from X and the pre-converted LZ(W_k), then estimate Â with LZ-encoded
//!   Q; SLZS and low-bit-multiply baselines for comparison.
//! * [`topk`] — the top-k stage: vanilla per-row selection (O(S·S·k)) and
//!   SADS distributed sorting with sphere-radius early termination
//!   (Sec. IV-B), both with comparison accounting. Exposed both as
//!   whole-row entry points and as the segment/merge primitives the
//!   sequence-sharded pipeline distributes across workers.
//! * [`distribution`] — the Type I/II/III row-distribution taxonomy of
//!   Fig. 9 and its classifier.
//! * [`hitrate`] — predicted-vs-true top-k hit-rate analysis (Fig. 17).
//! * [`dse`] — the Appendix-A design-space exploration over sub-segment
//!   size and top-k ratio.

pub mod distribution;
pub mod dse;
pub mod hitrate;
pub mod predictor;
pub mod topk;

pub use distribution::{classify_row, DistType};
pub use hitrate::hit_rate;
pub use predictor::{bits_for, PredictScheme, Predictor, PreparedPredict};
pub use topk::{
    merge_topk_candidates, merge_topk_candidates_into, sads_geometry, sads_merge, sads_merge_into,
    sads_segment_winners, sads_segment_winners_scratch, sads_topk, sads_topk_into, vanilla_topk,
    vanilla_topk_into, vanilla_topk_into_with, SadsParams, SadsStats, SegmentWinners, TopkScratch,
};
