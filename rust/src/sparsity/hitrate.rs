//! Predicted-vs-true top-k hit-rate analysis (Fig. 17a).
//!
//! The hit rate of a predictor at ratio `k` is
//! `|predicted top-k ∩ true top-k| / k`, averaged over rows. Fig. 17
//! profiles it layer-by-layer; the workload generator reproduces the
//! paper's depth trend (deeper layers → more separable scores → higher
//! hit rate) by sharpening the score distribution with depth.

use crate::tensor::{topk_indices, Mat};

/// Hit rate between two index sets (order-insensitive).
pub fn hit_rate(predicted: &[usize], truth: &[usize]) -> f64 {
    if truth.is_empty() {
        return 1.0;
    }
    let hits = truth.iter().filter(|t| predicted.contains(t)).count();
    hits as f64 / truth.len() as f64
}

/// Average top-k hit rate between an estimated and a true score matrix.
pub fn matrix_hit_rate(estimated: &Mat, exact: &Mat, k: usize) -> f64 {
    assert_eq!((estimated.rows, estimated.cols), (exact.rows, exact.cols));
    let mut acc = 0.0;
    for i in 0..exact.rows {
        let p = topk_indices(estimated.row(i), k);
        let t = topk_indices(exact.row(i), k);
        acc += hit_rate(&p, &t);
    }
    acc / exact.rows as f64
}

/// Output-level error induced by replacing the true top-k with the
/// predicted top-k: relative Frobenius error between masked-attention
/// outputs. This is the link from hit rate to task accuracy the paper's
/// Fig. 17(b) rests on.
pub fn selection_output_error(
    inp: &crate::attention::AttnInputs,
    predicted: &crate::attention::Selection,
    truth: &crate::attention::Selection,
) -> f32 {
    let po = crate::attention::masked_attention_oracle(inp, predicted);
    let to = crate::attention::masked_attention_oracle(inp, truth);
    po.rel_err(&to)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{AttnInputs, Selection};
    use crate::util::Rng;

    #[test]
    fn identical_sets_hit_1() {
        assert_eq!(hit_rate(&[1, 2, 3], &[3, 2, 1]), 1.0);
        assert_eq!(hit_rate(&[], &[]), 1.0);
    }

    #[test]
    fn disjoint_sets_hit_0() {
        assert_eq!(hit_rate(&[1, 2], &[3, 4]), 0.0);
    }

    #[test]
    fn partial_overlap() {
        assert!((hit_rate(&[1, 2, 3, 4], &[3, 4, 5, 6]) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn matrix_hit_rate_of_exact_is_1() {
        let mut rng = Rng::new(1);
        let m = Mat::randn(6, 40, 1.0, &mut rng);
        assert_eq!(matrix_hit_rate(&m, &m, 8), 1.0);
    }

    #[test]
    fn noisier_estimates_hit_less() {
        let mut rng = Rng::new(2);
        let exact = Mat::randn(16, 128, 1.0, &mut rng);
        let jitter = |sigma: f32, rng: &mut Rng| {
            Mat::from_vec(
                exact.rows,
                exact.cols,
                exact.data.iter().map(|&x| x + rng.normal_f32(0.0, sigma)).collect(),
            )
        };
        let mild = jitter(0.1, &mut rng);
        let harsh = jitter(2.0, &mut rng);
        let hm = matrix_hit_rate(&mild, &exact, 16);
        let hh = matrix_hit_rate(&harsh, &exact, 16);
        assert!(hm > hh, "mild {hm} !> harsh {hh}");
        assert!(hm > 0.8);
    }

    #[test]
    fn good_selection_means_small_output_error() {
        let mut rng = Rng::new(3);
        let q = Mat::randn(4, 16, 1.0, &mut rng);
        let k = Mat::randn(64, 16, 1.0, &mut rng);
        let v = Mat::randn(64, 16, 1.0, &mut rng);
        let inp = AttnInputs::new(&q, &k, &v);
        let truth = {
            let full = crate::attention::sufa::sort_selection_by_true_scores(
                &inp,
                &Selection::full(4, 64),
            );
            Selection { rows: full.rows.iter().map(|r| r[..16].to_vec()).collect() }
        };
        // Identical selection → zero error.
        assert_eq!(selection_output_error(&inp, &truth, &truth), 0.0);
        // Dropping to the *bottom* 16 keys → large error.
        let full =
            crate::attention::sufa::sort_selection_by_true_scores(&inp, &Selection::full(4, 64));
        let bad = Selection { rows: full.rows.iter().map(|r| r[48..].to_vec()).collect() };
        assert!(selection_output_error(&inp, &bad, &truth) > 0.2);
    }
}
