//! # STAR: cross-stage tiling sparse-attention accelerator — full-system reproduction
//!
//! This crate is Layer 3 of the three-layer Rust + JAX + Pallas stack described
//! in `DESIGN.md`. It contains:
//!
//! * [`tensor`], [`arith`] — numeric substrates: a minimal f32 matrix type,
//!   integer quantization, the leading-zero codec and the DLZS/SLZS
//!   approximate multipliers, and the operation-accounting machinery used to
//!   report "equivalent additions" the way the paper does.
//! * [`attention`] — counted software implementations of dense softmax
//!   attention, FlashAttention-2 and the paper's Sorted-Updating
//!   FlashAttention (SU-FA), in both ascending and descending update order.
//! * [`sparsity`] — the prediction stage (DLZS / SLZS predictors), the top-k
//!   stage (vanilla sorting and SADS distributed sorting with sphere-radius
//!   early termination), the Type I/II/III attention-distribution analysis,
//!   and the Appendix-A design-space exploration.
//! * [`pipeline`] — the four stages composed into one config-driven
//!   subsystem: tiled predict → top-k → KV-gen → SU-FA execution with
//!   per-stage accounting, shared by the bench harness, the native
//!   serving backend and the examples. Its `prefill`/`decode_step`
//!   entry points run the same stages causally for autoregressive
//!   serving, and [`pipeline::ShardedPipeline`] runs the same stages
//!   **sequence-sharded** across worker threads (executable
//!   Spatial-STAR / DRAttention) with bit-identical outputs at every
//!   worker count — for prefill and, via its `decode_step` over a
//!   partitioned view of the paged KV-cache, for decode (DESIGN.md
//!   §12). All three front-ends drive one allocation-free
//!   tile-execution core ([`pipeline::engine`]): per-worker
//!   [`pipeline::TileWorkspace`]s (pooled per shape class by
//!   [`pipeline::WorkspacePool`]) hold every stage buffer, the
//!   steady-state hot loop performs zero heap allocations (metered by
//!   [`util::allocmeter`]), and workspace capacity is reported next to
//!   the modeled SRAM budget (DESIGN.md §8).
//! * [`kvcache`] — the paged KV-cache + decode-session subsystem:
//!   block-granular pages (sized to the pipeline tile) holding K/V rows
//!   plus frozen per-row prediction operands, an LRU session store with
//!   capacity accounting and eviction/re-materialization, and the
//!   incremental per-row DLZS scorer decode steps run against cached
//!   pages.
//! * [`obs`] — observability: the zero-allocation span tracer (per-worker
//!   ring buffers recorded from the tile-engine stage bodies, exported as
//!   Chrome trace-event JSON via `star trace`), the HDR-style
//!   log-bucketed histograms behind the serving metrics and bench
//!   percentiles, and the Prometheus-style text exposition (DESIGN.md §9).
//! * [`sim`] — the cycle-level single-core STAR accelerator model, its
//!   energy/area models, the SRAM/DRAM memory system, the A100 roofline
//!   model and the FACT/Energon/ELSA/SpAtten/Simba baselines.
//! * [`spatial`] — the 2D-mesh NoC, the MRCA communication algorithm
//!   (Alg. 1), the DRAttention dataflow and the Ring-Attention baseline,
//!   plus the 5×5/6×6 multi-core spatial simulator. The *analytic*
//!   counterpart of [`pipeline::ShardedPipeline`], which executes the
//!   same dataflow on real threads (`star bench spatial-exec`
//!   cross-validates the two).
//! * `runtime` — the PJRT engine that loads the AOT-compiled HLO-text
//!   artifacts produced by `python/compile/aot.py` and executes them on the
//!   request path (python never runs at serving time). Gated behind the
//!   off-by-default `pjrt` cargo feature: it needs the `xla` crate, which
//!   the offline build environment does not ship.
//! * [`coordinator`] — the LTPP serving layer: request router (with
//!   batch-target admission), dynamic batcher (decode steps re-enter it
//!   each turn and mix with prefill chunks — continuous batching), tiled
//!   out-of-order scheduler and a thread-based session-aware server.
//! * [`workload`], [`config`], [`bench`] — workload/trace generation, the
//!   config system, and the harness that regenerates every table and figure
//!   of the paper's evaluation.

pub mod arith;
pub mod attention;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod kvcache;
pub mod obs;
pub mod pipeline;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sim;
pub mod sparsity;
pub mod spatial;
pub mod tensor;
pub mod testing;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
