//! Hand-rolled CLI argument parsing (clap is unavailable offline).
//!
//! Grammar: `star <subcommand> [--flag] [--key value] ...`
//! Unknown flags are collected and reported by the caller.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, positional args, and `--key value` /
/// `--flag` options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // `--key=value` or `--key value` or boolean `--flag`.
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse("bench fig19 extra");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["fig19", "extra"]);
    }

    #[test]
    fn options_both_styles() {
        let a = parse("sim --seq 1024 --model=gpt2 --record");
        assert_eq!(a.get_usize("seq", 0), 1024);
        assert_eq!(a.get("model"), Some("gpt2"));
        assert!(a.flag("record"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --a --b");
        assert!(a.flag("a") && a.flag("b"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_or("mode", "fast"), "fast");
        assert_eq!(a.get_f64("ratio", 0.25), 0.25);
    }
}
