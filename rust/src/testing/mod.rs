//! Mini property-testing framework (proptest is unavailable offline).
//!
//! Provides seeded random-case generation with failure reporting and a
//! simple halving shrink for integer-vector inputs. Used by the unit tests
//! and `rust/tests/prop_invariants.rs`.

use crate::util::Rng;

/// Number of cases per property (override with `STAR_PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("STAR_PROP_CASES").ok().and_then(|v| v.parse().ok()).unwrap_or(64)
}

/// Run `prop` on `cases` random inputs produced by `gen`. On failure, retry
/// with shrunken inputs produced by `shrink` (if any) and panic with the
/// smallest failing case found.
pub fn check_with<T: Clone + std::fmt::Debug>(
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: keep taking the first failing shrink candidate.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut progress = true;
            let mut rounds = 0;
            while progress && rounds < 200 {
                progress = false;
                rounds += 1;
                for cand in shrink(&best) {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        progress = true;
                        break;
                    }
                }
            }
            panic!(
                "property failed (seed={seed}, case={case})\n  input (shrunk): {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Run `prop` on `default_cases()` random inputs without shrinking.
pub fn check<T: Clone + std::fmt::Debug>(
    seed: u64,
    gen: impl FnMut(&mut Rng) -> T,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    check_with(seed, default_cases(), gen, |_| Vec::new(), prop);
}

/// Assert helper producing `Result<(), String>` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Shrinker for `Vec<T>`: halve the length and drop single elements.
pub fn shrink_vec<T: Clone>(v: &[T]) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.len() > 1 {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
        for i in 0..v.len().min(8) {
            let mut c = v.to_vec();
            c.remove(i);
            out.push(c);
        }
    }
    out
}

/// Shrinker for `usize`: towards zero by halving.
pub fn shrink_usize(x: usize) -> Vec<usize> {
    if x == 0 {
        Vec::new()
    } else {
        vec![x / 2, x - 1]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, |r| r.below(100), |&x| {
            prop_assert!(x < 100, "x={x} out of range");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case() {
        check(2, |r| r.below(100), |&x| {
            prop_assert!(x < 50, "x={x} >= 50");
            Ok(())
        });
    }

    #[test]
    fn shrink_finds_small_counterexample() {
        // Property: all vectors have length < 4. Shrinking should find a
        // counterexample of exactly length 4.
        let caught = std::panic::catch_unwind(|| {
            check_with(
                3,
                64,
                |r| (0..r.range(0, 20)).map(|i| i as u32).collect::<Vec<u32>>(),
                |v| shrink_vec(v),
                |v| {
                    prop_assert!(v.len() < 4, "len={}", v.len());
                    Ok(())
                },
            );
        });
        let msg = format!("{:?}", caught.unwrap_err().downcast_ref::<String>().unwrap());
        assert!(msg.contains("len=4"), "expected shrink to len=4, got: {msg}");
    }

    #[test]
    fn shrink_usize_terminates() {
        let mut x = 1_000_000usize;
        let mut steps = 0;
        while x > 0 {
            x = shrink_usize(x)[0];
            steps += 1;
            assert!(steps < 64);
        }
    }
}
