//! Property: pooled, dirty workspaces never change the math — and the
//! warm hot path allocates nothing.
//!
//! The tile engine (`star::pipeline::engine`) runs every stage inside
//! reusable per-worker buffers ([`star::pipeline::TileWorkspace`],
//! pooled by [`star::pipeline::WorkspacePool`]). Two contracts are under
//! test here:
//!
//! 1. **Dirty-workspace parity.** A sequence of heterogeneous requests
//!    (varying T/S/tile sizes, prefill interleaved with decode and
//!    sharded runs) through ONE pool is bit-identical — outputs,
//!    selections, stalls, per-stage ops — to fresh-allocation runs.
//!    Leftover state in a reused workspace must be invisible.
//! 2. **Zero-allocation steady state.** This test binary installs the
//!    counting allocator, so `hot_path_allocs` is a real measurement:
//!    once a workspace is warm for a shape class, the metered stage
//!    cores must not touch the heap.

#[global_allocator]
static ALLOC: star::util::allocmeter::CountingAllocator =
    star::util::allocmeter::CountingAllocator;

use star::attention::Selection;
use star::kvcache::{SessionConfig, SessionStore};
use star::pipeline::{
    PipelineConfig, PipelineInputs, ShardedPipeline, SparseAttentionPipeline, WorkspacePool,
};
use star::tensor::Mat;
use star::util::{allocmeter, Rng};

fn mats(t: usize, s: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
    let mut rng = Rng::new(seed);
    (
        Mat::randn(t, d, 1.0, &mut rng),
        Mat::randn(s, d, 1.0, &mut rng),
        Mat::randn(s, d, 1.0, &mut rng),
    )
}

fn sub(m: &Mat, lo: usize, hi: usize) -> Mat {
    Mat::from_fn(hi - lo, m.cols, |i, j| m.at(lo + i, j))
}

#[test]
fn counting_allocator_is_live_in_this_binary() {
    let a0 = allocmeter::thread_allocs();
    let v: Vec<u64> = Vec::with_capacity(64);
    assert!(allocmeter::thread_allocs() > a0, "allocation meter must count");
    assert!(allocmeter::installed());
    drop(v);
}

#[test]
fn heterogeneous_requests_through_one_pool_are_bit_identical() {
    // One pool serves everything, in an order chosen so every request
    // inherits a workspace left dirty by a *different* shape: big
    // prefill → small prefill → sharded → decode session → prefill
    // again. Each pooled result must equal the fresh-allocation result.
    let pool = WorkspacePool::new();

    // Prefill shapes: (t, s, d, tile, keep).
    let shapes = [
        (24usize, 96usize, 16usize, 8usize, 0.25f64),
        (7, 130, 16, 64, 0.4),
        (16, 64, 16, 5, 0.25),
    ];
    for (round, &(t, s, d, tile, keep)) in shapes.iter().enumerate() {
        let (q, k, v) = mats(t, s, d, 100 + round as u64);
        let inputs = PipelineInputs::qkv(&q, &k, &v);
        let cfg = PipelineConfig::star().with_keep(keep).with_tile(tile).with_threads(1);
        let fresh = SparseAttentionPipeline::new(cfg).run(&inputs);
        let pooled = SparseAttentionPipeline::new(cfg).run_pooled(&inputs, &pool);
        let tag = format!("prefill round {round}");
        assert_eq!(pooled.selection, fresh.selection, "{tag}: selection drift");
        assert_eq!(pooled.out.max_abs_diff(&fresh.out), 0.0, "{tag}: output drift");
        assert_eq!(pooled.stalls, fresh.stalls, "{tag}: stall drift");
        assert_eq!(pooled.ops.predict, fresh.ops.predict, "{tag}: predict ops drift");
        assert_eq!(pooled.ops.topk, fresh.ops.topk, "{tag}: topk ops drift");
        assert_eq!(pooled.ops.formal, fresh.ops.formal, "{tag}: formal ops drift");

        // Sharded run on the same (now dirty) pool.
        let sharded = ShardedPipeline::new(cfg, 3).run_pooled(&inputs, &pool);
        assert_eq!(sharded.selection, fresh.selection, "{tag}: sharded selection drift");
        assert_eq!(sharded.out.max_abs_diff(&fresh.out), 0.0, "{tag}: sharded output drift");
        assert_eq!(sharded.stalls, fresh.stalls, "{tag}: sharded stall drift");
    }

    // A decode session (interleaved chunk sizes) through the same pool
    // vs a fresh-pool session.
    let (n, d) = (40usize, 16usize);
    let (q, k, v) = mats(n, n, d, 777);
    let cfg = PipelineConfig::star().with_keep(0.3).with_tile(8).with_threads(1);
    let pipe = SparseAttentionPipeline::new(cfg);
    let run_session = |pool: &WorkspacePool| -> (Mat, Selection) {
        let mut store = SessionStore::new(SessionConfig::for_pipeline(&cfg, d, 0));
        let mut out = Mat::zeros(n, d);
        let mut sel_rows = Vec::new();
        let mut at = 0usize;
        for &c in &[5usize, 1, 9, 1, 1, 16, 7] {
            let r = pipe
                .decode_step_pooled(
                    &mut store,
                    1,
                    &sub(&q, at, at + c),
                    &sub(&k, at, at + c),
                    &sub(&v, at, at + c),
                    pool,
                )
                .expect("decode step");
            for i in 0..c {
                out.row_mut(at + i).copy_from_slice(r.out.row(i));
            }
            sel_rows.extend(r.selection.rows);
            at += c;
        }
        assert_eq!(at, n);
        (out, Selection { rows: sel_rows })
    };
    let (fresh_out, fresh_sel) = run_session(&WorkspacePool::new());
    let (pooled_out, pooled_sel) = run_session(&pool);
    assert_eq!(pooled_sel, fresh_sel, "decode selection drift through dirty pool");
    assert_eq!(pooled_out.max_abs_diff(&fresh_out), 0.0, "decode output drift through dirty pool");

    // And one more prefill after the decode traffic.
    let (q, k, v) = mats(12, 200, 16, 888);
    let inputs = PipelineInputs::qkv(&q, &k, &v);
    let cfg = PipelineConfig::star().with_keep(0.2).with_threads(1);
    let fresh = SparseAttentionPipeline::new(cfg).run(&inputs);
    let pooled = SparseAttentionPipeline::new(cfg).run_pooled(&inputs, &pool);
    assert_eq!(pooled.selection, fresh.selection);
    assert_eq!(pooled.out.max_abs_diff(&fresh.out), 0.0);
}

#[test]
fn dirty_pool_parity_across_configurations() {
    // The dense oracle, the DS baseline and a SLZS/ascend mix all share
    // one pool (same shape class ⇒ same reused workspace), immediately
    // after each other.
    let (t, s, d) = (18usize, 96usize, 16usize);
    let (q, k, v) = mats(t, s, d, 4242);
    let inputs = PipelineInputs::qkv(&q, &k, &v);
    let pool = WorkspacePool::new();
    let configs = [
        PipelineConfig::star().with_keep(0.3),
        PipelineConfig::ds_baseline().with_keep(0.3),
        PipelineConfig::dense_oracle(),
        PipelineConfig {
            predict: star::sim::pipeline::PredictKind::Slzs,
            formal: star::sim::pipeline::FormalKind::SufaAscend,
            ..PipelineConfig::star().with_keep(0.4)
        },
    ];
    for (i, cfg) in configs.iter().enumerate() {
        let cfg = cfg.with_threads(1);
        let fresh = SparseAttentionPipeline::new(cfg).run(&inputs);
        let pooled = SparseAttentionPipeline::new(cfg).run_pooled(&inputs, &pool);
        assert_eq!(pooled.selection, fresh.selection, "config {i}: selection drift");
        assert_eq!(pooled.out.max_abs_diff(&fresh.out), 0.0, "config {i}: output drift");
        assert_eq!(pooled.stalls, fresh.stalls, "config {i}: stall drift");
    }
}

#[test]
fn warm_workspaces_allocate_nothing_on_the_hot_path() {
    // Prefill: the second identical-shape run must meter zero
    // allocations in its stage cores.
    let (t, s, d) = (24usize, 128usize, 16usize);
    let (q, k, v) = mats(t, s, d, 31337);
    let inputs = PipelineInputs::qkv(&q, &k, &v);
    let pool = WorkspacePool::new();
    let pipe = SparseAttentionPipeline::new(
        PipelineConfig::star().with_keep(0.25).with_tile(8).with_threads(1),
    );
    let _warmup = pipe.run_pooled(&inputs, &pool);
    let warm = pipe.run_pooled(&inputs, &pool);
    assert_eq!(warm.hot_path_allocs, 0, "warm prefill hot loop allocated");
    assert!(warm.workspace_bytes > 0);

    // Decode: every step after the pool-warming prefill must meter
    // zero, even as the causal context grows (capacity maintenance is
    // outside the metered core by design).
    let (n, dd) = (32usize, 16usize);
    let (q, k, v) = mats(n, n, dd, 555);
    let cfg = PipelineConfig::star().with_keep(0.3).with_tile(8).with_threads(1);
    let pipe = SparseAttentionPipeline::new(cfg);
    let mut store = SessionStore::new(SessionConfig::for_pipeline(&cfg, dd, 0));
    pipe.decode_step_pooled(&mut store, 1, &sub(&q, 0, 8), &sub(&k, 0, 8), &sub(&v, 0, 8), &pool)
        .expect("warming prefill chunk");
    for pos in 8..n {
        let r = pipe
            .decode_step_pooled(
                &mut store,
                1,
                &sub(&q, pos, pos + 1),
                &sub(&k, pos, pos + 1),
                &sub(&v, pos, pos + 1),
                &pool,
            )
            .expect("decode step");
        assert_eq!(r.hot_path_allocs, 0, "decode step at pos {pos} allocated in its stage core");
    }

    // Sharded: the second identical run on warm per-worker workspaces
    // must meter zero in the home gather/formal cores.
    let sharded = ShardedPipeline::new(cfg, 2);
    let inputs = PipelineInputs::qkv(&q, &k, &v);
    let _warmup = sharded.run_pooled(&inputs, &pool);
    let warm = sharded.run_pooled(&inputs, &pool);
    assert_eq!(warm.hot_path_allocs, 0, "warm sharded home phase allocated");
}
