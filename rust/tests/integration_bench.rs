//! Integration: the bench trajectory output for the measured
//! sequence-sharded study — `star bench spatial-exec` must write a
//! schema-valid `BENCH_spatial_exec.json` with a non-empty, ascending
//! shard-count axis and a passing parity flag.

use star::bench::spatial_exec::{payload, spatial_exec_with};
use star::bench::trajectory;
use star::util::json::Json;

#[test]
fn spatial_exec_writes_a_schema_valid_trajectory() {
    // Small sizes: schema and correctness only (wall-clock magnitudes
    // are asserted nowhere — CI machines are noisy). The CLI path
    // (`star bench spatial-exec`) goes through the same payload builder
    // and trajectory writer exercised here, at the default sizes.
    let report = spatial_exec_with(24, 160, 16, 0.25, &[1, 2, 4]);
    let dir = std::env::temp_dir().join("star_spatial_exec_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = trajectory::write_to(&dir, "spatial_exec", payload(&report)).unwrap();
    assert!(
        path.file_name().unwrap().to_str().unwrap() == "BENCH_spatial_exec.json",
        "trajectory file must be BENCH_spatial_exec.json, got {path:?}"
    );

    // Round-trip through the JSON parser and validate the schema.
    let text = std::fs::read_to_string(&path).unwrap();
    let j = Json::parse(&text).unwrap();
    assert_eq!(j.get("bench").unwrap().as_str(), Some("spatial_exec"));
    assert_eq!(j.get("parity_ok").unwrap().as_bool(), Some(true), "bit-parity must hold");
    assert!(j.get("single_core_wall_s").unwrap().as_f64().unwrap() > 0.0);

    let columns = j.get("columns").unwrap().as_arr().unwrap();
    let want = [
        "shards",
        "wall_s",
        "speedup",
        "ring_steps",
        "ring_payload_bytes",
        "gathered_kv_rows",
        "analytic_total_s",
        "analytic_speedup",
    ];
    assert_eq!(columns.len(), want.len());
    for (c, w) in columns.iter().zip(want) {
        assert_eq!(c.as_str(), Some(w));
    }

    let rows = j.get("rows").unwrap().as_arr().unwrap();
    assert!(!rows.is_empty(), "trajectory must be non-empty");
    let mut prev_shards = 0usize;
    for r in rows {
        let cells = r.as_arr().unwrap();
        assert_eq!(cells.len(), want.len());
        let shards = cells[0].as_usize().unwrap();
        assert!(shards > prev_shards, "shard-count axis must ascend: {shards} after {prev_shards}");
        prev_shards = shards;
        assert!(cells[1].as_f64().unwrap() > 0.0, "wall time positive");
        assert!(cells[2].as_f64().unwrap() > 0.0, "speedup positive");
        assert_eq!(cells[3].as_usize().unwrap(), shards, "ring steps = worker count");
        assert!(cells[6].as_f64().unwrap() > 0.0, "analytic prediction present");
    }

    std::fs::remove_file(&path).ok();
}
