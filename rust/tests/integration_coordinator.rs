//! Integration: the serving stack (router → batcher → scheduler →
//! backend) under load, with the simulated and native backends.

use star::attention::{masked_attention_oracle, AttnInputs};
use star::config::AccelConfig;
use star::coordinator::{
    Backend, BatcherConfig, Request, Router, Server, ServerConfig, Stage, TiledScheduler, Variant,
};
use star::pipeline::{PipelineConfig, PipelineInputs, SparseAttentionPipeline};
use star::sim::dram::DramChannel;
use star::sim::pipeline::FeatureSet;
use star::tensor::Mat;
use star::util::Rng;
use std::collections::BTreeMap;

fn server(target_t: usize, workers: usize) -> Server {
    let router = Router::new(vec![
        Variant { name: "attn_small".into(), model: "tiny".into(), max_t: 128, s: 512 },
        Variant { name: "attn_big".into(), model: "tiny".into(), max_t: 128, s: 4096 },
    ]);
    let backend = Backend::Sim {
        feats: FeatureSet::star(),
        accel: AccelConfig::default(),
        dram: DramChannel::accel_256(),
        d: 64,
        h: 768,
        keep: 0.2,
        time_scale: 0.0,
    };
    Server::start(
        router,
        backend,
        ServerConfig { batcher: BatcherConfig { target_t, max_wait_s: 1e-3 }, workers },
    )
}

#[test]
fn hundred_requests_across_buckets() {
    let srv = server(64, 4);
    let mut rng = Rng::new(5);
    let mut rxs = Vec::new();
    for id in 0..100u64 {
        let s = if rng.chance(0.5) { 256 } else { 2048 };
        rxs.push(srv.submit(Request::new(id, "tiny", 8, s, 0.0)).unwrap());
    }
    let mut small = 0;
    let mut big = 0;
    for rx in rxs {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        match resp.variant.as_str() {
            "attn_small" => small += 1,
            "attn_big" => big += 1,
            other => panic!("unexpected variant {other}"),
        }
    }
    assert_eq!(small + big, 100);
    assert!(small > 10 && big > 10, "both buckets used: {small}/{big}");
    let snap = srv.shutdown();
    assert_eq!(snap.requests, 100);
    assert!(snap.batch_rows.mean > 8.0, "batching actually batched: {}", snap.batch_rows.mean);
    assert!(snap.latency.p99 >= snap.latency.p50, "histogram percentiles are ordered");
    assert_eq!(snap.latency.count, 100, "every response recorded in the latency histogram");
}

#[test]
fn shutdown_flushes_everything() {
    let srv = server(10_000, 1); // never fills naturally
    let mut rxs = Vec::new();
    for id in 0..5u64 {
        rxs.push(srv.submit(Request::new(id, "tiny", 4, 256, 0.0)).unwrap());
    }
    // Don't wait for the timeout: shut down immediately.
    let snap = srv.shutdown();
    assert_eq!(snap.requests, 5);
    for rx in rxs {
        assert!(rx.try_recv().is_ok(), "response delivered on shutdown flush");
    }
}

#[test]
fn native_backend_round_trip_matches_inline_pipeline() {
    // End to end through router → batcher → workers, the server must
    // return exactly what an inline pipeline run over the same Q and KV
    // context computes — real sparse attention, served.
    let (s, d) = (512usize, 32usize);
    let mut rng = Rng::new(77);
    let kctx = Mat::randn(s, d, 1.0, &mut rng);
    let vctx = Mat::randn(s, d, 1.0, &mut rng);
    let pipeline = PipelineConfig::star().with_threads(1);
    let mut contexts = BTreeMap::new();
    contexts.insert("attn_native".to_string(), (kctx.clone(), vctx.clone()));
    let router = Router::new(vec![Variant {
        name: "attn_native".into(),
        model: "tiny".into(),
        max_t: 128,
        s,
    }]);
    let srv = Server::start(
        router,
        Backend::native(pipeline, contexts),
        // Submitting one request at a time (awaiting each response before
        // the next submit) keeps every batch single-request, so each
        // response is comparable to an inline pipeline run. (target_t = 1
        // would instead route every request onto the sharded path —
        // this test exercises the batched native path specifically.)
        ServerConfig { batcher: BatcherConfig { target_t: 8, max_wait_s: 1e-4 }, workers: 2 },
    );
    for id in 0..8u64 {
        let t = 4 + (id as usize % 3) * 2;
        let q = Mat::randn(t, d, 1.0, &mut rng);
        let mut req = Request::new(id, "tiny", t, s, 0.0);
        req.q = Some(q.clone());
        let rx = srv.submit(req).unwrap();
        let resp = rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        assert_eq!(resp.variant, "attn_native");
        let out = resp.output.expect("native round trip returns outputs");
        let inline = SparseAttentionPipeline::new(PipelineConfig::star().with_threads(1))
            .run(&PipelineInputs::qkv(&q, &kctx, &vctx));
        assert_eq!(
            out.max_abs_diff(&inline.out),
            0.0,
            "served output must equal the inline pipeline result"
        );
        // And that result is the exact softmax over the pipeline's selection.
        let inp = AttnInputs::new(&q, &kctx, &vctx);
        let oracle = masked_attention_oracle(&inp, &inline.selection);
        assert!(out.max_abs_diff(&oracle) < 1e-4);
    }
    let snap = srv.shutdown();
    assert_eq!(snap.requests, 8);
    assert!(snap.stage_predict_s > 0.0 && snap.stage_formal_s > 0.0, "per-stage metrics recorded");
    assert_eq!(snap.rejected, 0);
}

#[test]
fn admission_serves_over_target_prefill_and_decode() {
    // Regression, third generation: a t > target_t request used to flow
    // through unchecked and seal an over-target batch (gen 1); then
    // Router::admit served over-target *prefill* sharded but rejected
    // over-target *decode* outright (gen 2). With the partitioned-cache
    // decode engine both request kinds now ride the sharded path —
    // inverted from gen 2: no width is ever rejected, only an unknown
    // model or an impossible context.
    let srv = server(16, 2);
    // Routable by shape (max_t = 128) but wider than target_t = 16:
    // served via the sharded path.
    let rx = srv.submit(Request::new(1, "tiny", 48, 256, 0.0)).unwrap();
    let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
    assert_eq!(resp.variant, "attn_small", "over-target prefill must be served: {resp:?}");
    // Over-target decode is served now too (the gen-2 rejection, inverted).
    let d = 8;
    let (q, k, v) = (Mat::zeros(48, d), Mat::zeros(48, d), Mat::zeros(48, d));
    let rx = srv.submit(Request::decode(2, "tiny", 5, q, k, v, 48, 0.0)).unwrap();
    let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
    assert_eq!(resp.variant, "attn_small", "over-target decode must be served: {resp:?}");
    // A within-target request still serves normally.
    let rx = srv.submit(Request::new(3, "tiny", 16, 256, 0.0)).unwrap();
    let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
    assert_eq!(resp.variant, "attn_small");
    // Only genuinely unroutable requests reject: an unknown model …
    let rx = srv.submit(Request::new(4, "nope", 4, 256, 0.0)).unwrap();
    let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
    assert!(resp.variant.starts_with("rejected"), "unknown model must reject: {resp:?}");
    // … or a decode step claiming a context beyond every bucket.
    let (q, k, v) = (Mat::zeros(48, d), Mat::zeros(48, d), Mat::zeros(48, d));
    let rx = srv.submit(Request::decode(5, "tiny", 5, q, k, v, 9999, 0.0)).unwrap();
    let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
    assert!(
        resp.variant.starts_with("rejected") && resp.variant.contains("exceeds"),
        "impossible context must still reject, got {:?}",
        resp.variant
    );
    assert!(resp.output.is_none());
    let snap = srv.shutdown();
    assert_eq!(snap.rejected, 2, "width never rejects; model and context still do");
}

#[test]
fn over_target_prefill_serves_bit_identical_sharded_outputs() {
    // The t > target_t prefill path end to end through the native
    // backend: admitted as Admission::Sharded, executed on the
    // ShardedPipeline, and — the engine's contract — bit-identical to
    // what the single-core pipeline computes inline over the same
    // context. Per-shard metrics must land in the snapshot.
    let (s, d) = (256usize, 16usize);
    let mut rng = Rng::new(91);
    let kctx = Mat::randn(s, d, 1.0, &mut rng);
    let vctx = Mat::randn(s, d, 1.0, &mut rng);
    let pipeline = PipelineConfig::star().with_keep(0.25).with_threads(1);
    let mut contexts = BTreeMap::new();
    contexts.insert("attn_native".to_string(), (kctx.clone(), vctx.clone()));
    let router = Router::new(vec![Variant {
        name: "attn_native".into(),
        model: "tiny".into(),
        max_t: 128,
        s,
    }]);
    let srv = Server::start(
        router,
        Backend::native(pipeline, contexts).with_shards(2),
        ServerConfig { batcher: BatcherConfig { target_t: 16, max_wait_s: 1e-3 }, workers: 2 },
    );
    // Wider than target_t AND wider than the variant's max_t: the
    // sharded path partitions rows itself.
    let t = 160usize;
    let q = Mat::randn(t, d, 1.0, &mut rng);
    let mut req = Request::new(1, "tiny", t, s, 0.0);
    req.q = Some(q.clone());
    let rx = srv.submit(req).unwrap();
    let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
    assert_eq!(resp.variant, "attn_native");
    let out = resp.output.expect("sharded prefill returns outputs");
    let inline = SparseAttentionPipeline::new(PipelineConfig::star().with_keep(0.25).with_threads(1))
        .run(&PipelineInputs::qkv(&q, &kctx, &vctx));
    assert_eq!(
        out.max_abs_diff(&inline.out),
        0.0,
        "sharded serving must equal the single-core pipeline bit for bit"
    );
    let snap = srv.shutdown();
    assert_eq!(snap.requests, 1);
    assert_eq!(snap.rejected, 0);
    assert_eq!(snap.sharded_prefills, 1);
    assert_eq!(snap.shard_stage_s.len(), 2, "per-shard timings recorded");
    assert!(snap.ring_steps >= 2 && snap.gathered_kv_rows > 0);
    assert_eq!(snap.ttft_sharded.count, 1, "sharded prefill lands in its TTFT class");
    assert_eq!(snap.ttft_prefill.count, 0);
}

#[test]
fn over_target_decode_serves_bit_identical_sharded_outputs() {
    use star::kvcache::{SessionConfig, SessionStore};

    // End to end through admission: one decode session whose chunks
    // straddle the batch target. Over-target chunks ride the
    // partitioned-cache sharded decode engine
    // (ShardedPipeline::decode_step_pooled), under-target steps the
    // batched native path — and the served stream must equal an offline
    // single-core run bit for bit regardless of which path each step
    // took (the engine's parity contract). Admission stays monotone as
    // the cached context grows: nothing is rejected until a step claims
    // a context beyond every bucket.
    let (s, d) = (512usize, 16usize);
    let pipeline = PipelineConfig::star().with_keep(0.3).with_tile(8).with_threads(1);
    let router = Router::new(vec![Variant {
        name: "attn_native".into(),
        model: "tiny".into(),
        max_t: 128,
        s,
    }]);
    let store = SessionStore::new(SessionConfig::for_pipeline(&pipeline, d, 0));
    let srv = Server::start(
        router,
        Backend::native_with_sessions(pipeline, BTreeMap::new(), store).with_shards(2),
        ServerConfig { batcher: BatcherConfig { target_t: 16, max_wait_s: 1e-3 }, workers: 2 },
    );

    let n = 74usize; // 48 (sharded) + 6×1 (batched) + 20 (sharded)
    let mut rng = Rng::new(23);
    let q = Mat::randn(n, d, 1.0, &mut rng);
    let k = Mat::randn(n, d, 1.0, &mut rng);
    let v = Mat::randn(n, d, 1.0, &mut rng);
    let sub = |m: &Mat, lo: usize, hi: usize| Mat::from_fn(hi - lo, d, |i, j| m.at(lo + i, j));

    let mut served = Mat::zeros(n, d);
    let mut id = 0u64;
    let mut step = |lo: usize, hi: usize, served: &mut Mat| {
        id += 1;
        let rx = srv
            .submit(Request::decode(
                id,
                "tiny",
                11,
                sub(&q, lo, hi),
                sub(&k, lo, hi),
                sub(&v, lo, hi),
                hi,
                0.0,
            ))
            .unwrap();
        let resp = rx.recv_timeout(std::time::Duration::from_secs(30)).unwrap();
        assert_eq!(resp.variant, "attn_native", "decode chunk [{lo},{hi}) must serve");
        let out = resp.output.expect("decode output");
        assert_eq!((out.rows, out.cols), (hi - lo, d));
        for i in 0..(hi - lo) {
            served.row_mut(lo + i).copy_from_slice(out.row(i));
        }
    };
    step(0, 48, &mut served); // t = 48 > 16 → Admission::Sharded
    for p in 48..54 {
        step(p, p + 1, &mut served); // t = 1 → batched decode
    }
    step(54, n, &mut served); // t = 20 > 16 → sharded again

    // The session grew from 0 to 74 cached rows without a rejection. A
    // step *claiming* a context beyond every bucket is refused at
    // admission — before touching the session.
    let bad =
        Request::decode(99, "tiny", 11, sub(&q, 0, 1), sub(&k, 0, 1), sub(&v, 0, 1), 9999, 0.0);
    let rx = srv.submit(bad).unwrap();
    let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
    assert!(
        resp.variant.starts_with("rejected") && resp.variant.contains("exceeds"),
        "claimed context over every bucket must reject, got {:?}",
        resp.variant
    );
    assert!(resp.output.is_none());

    // Served outputs must equal an offline single-core run over the same
    // token stream, bit for bit — both sharded and batched steps
    // (PipelineConfig is Copy; `pipeline` is the exact server config).
    let mut offline_store = SessionStore::new(SessionConfig::for_pipeline(&pipeline, d, 0));
    let offline = SparseAttentionPipeline::new(pipeline)
        .prefill(&mut offline_store, 1, &q, &k, &v)
        .unwrap();
    assert_eq!(
        served.max_abs_diff(&offline.out),
        0.0,
        "mixed sharded/batched served decode != offline single-core decode"
    );

    let snap = srv.shutdown();
    assert_eq!(snap.requests, 8, "all eight decode steps served");
    assert_eq!(snap.rejected, 1, "only the impossible-context claim rejected");
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.sharded_decodes, 2, "the two over-target chunks ran sharded");
    assert_eq!(snap.decode_steps, 8, "sharded decode steps count as decode steps too");
    assert_eq!(snap.decode_tokens, n as u64, "the rejected claim appended nothing");
    assert_eq!(snap.sharded_prefills, 0);
    assert_eq!(snap.ring_steps, 2, "one candidate-scatter round per sharded step at 2 workers");
    assert!(snap.ring_payload_bytes > 0 && snap.gathered_kv_rows > 0);
    assert_eq!(snap.shard_stage_s.len(), 2, "per-shard timings recorded");
    assert_eq!(snap.tpot_decode.count, 8, "every decode step records TPOT, sharded included");
    assert_eq!(snap.ttft_sharded.count, 0, "sharded decode is TPOT, not sharded TTFT");
}

#[test]
fn decode_sessions_serve_through_continuous_batching() {
    use star::kvcache::{SessionConfig, SessionStore};

    let (s, d) = (512usize, 16usize);
    let pipeline = PipelineConfig::star().with_keep(0.3).with_tile(8).with_threads(1);
    let mut rng = Rng::new(41);
    let kctx = Mat::randn(s, d, 1.0, &mut rng);
    let vctx = Mat::randn(s, d, 1.0, &mut rng);
    let mut contexts = BTreeMap::new();
    contexts.insert("attn_native".to_string(), (kctx.clone(), vctx.clone()));
    let router = Router::new(vec![Variant {
        name: "attn_native".into(),
        model: "tiny".into(),
        max_t: 128,
        s,
    }]);
    let store = SessionStore::new(SessionConfig::for_pipeline(&pipeline, d, 0));
    let srv = Server::start(
        router,
        Backend::native_with_sessions(pipeline, contexts, store),
        ServerConfig { batcher: BatcherConfig { target_t: 32, max_wait_s: 1e-3 }, workers: 2 },
    );

    // Token stream for one conversation: a 12-token prefill chunk, then
    // 6 single-token decode steps, interleaved with stateless prefill
    // requests so batches mix both kinds.
    let n = 18usize;
    let q = Mat::randn(n, d, 1.0, &mut rng);
    let k = Mat::randn(n, d, 1.0, &mut rng);
    let v = Mat::randn(n, d, 1.0, &mut rng);
    let sub = |m: &Mat, lo: usize, hi: usize| Mat::from_fn(hi - lo, d, |i, j| m.at(lo + i, j));

    let mut served = Mat::zeros(n, d);
    let mut id = 0u64;
    let mut step = |lo: usize, hi: usize, served: &mut Mat| {
        id += 1;
        let rx = srv
            .submit(Request::decode(
                id,
                "tiny",
                7,
                sub(&q, lo, hi),
                sub(&k, lo, hi),
                sub(&v, lo, hi),
                hi,
                0.0,
            ))
            .unwrap();
        // Stateless traffic in the same window.
        let mut req = Request::new(10_000 + id, "tiny", 4, s, 0.0);
        req.q = Some(Mat::randn(4, d, 1.0, &mut Rng::new(id)));
        let rx2 = srv.submit(req).unwrap();
        let resp = rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        let out = resp.output.expect("decode output");
        assert_eq!((out.rows, out.cols), (hi - lo, d));
        for i in 0..(hi - lo) {
            served.row_mut(lo + i).copy_from_slice(out.row(i));
        }
        let resp2 = rx2.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        assert!(resp2.output.is_some(), "stateless prefill still served");
    };
    step(0, 12, &mut served);
    for p in 12..n {
        step(p, p + 1, &mut served);
    }

    // Ordering guard: a step claiming the wrong post-append context
    // length is rejected per-request — no output, no session mutation,
    // and the rest of the batch is unaffected.
    let bad =
        Request::decode(500, "tiny", 7, sub(&q, 0, 1), sub(&k, 0, 1), sub(&v, 0, 1), 99, 0.0);
    let rx = srv.submit(bad).unwrap();
    let resp = rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
    assert!(
        resp.variant.starts_with("error:") && resp.variant.contains("out of order"),
        "expected an out-of-order rejection, got {:?}",
        resp.variant
    );
    assert!(resp.output.is_none());

    // Served decode outputs must equal an offline run over the same
    // token stream, bit for bit (PipelineConfig is Copy; `pipeline` is
    // the exact config the server ran).
    let mut offline_store = SessionStore::new(SessionConfig::for_pipeline(&pipeline, d, 0));
    let offline = SparseAttentionPipeline::new(pipeline)
        .prefill(&mut offline_store, 1, &q, &k, &v)
        .unwrap();
    assert_eq!(served.max_abs_diff(&offline.out), 0.0, "served decode != offline decode");

    let snap = srv.shutdown();
    assert_eq!(snap.decode_steps, 7, "one prefill chunk + 6 decode steps");
    assert_eq!(snap.decode_tokens, n as u64, "the rejected step appended nothing");
    assert!(snap.cache_page_hits > 0, "cache hits recorded");
    assert_eq!(snap.failed, 1, "exactly the out-of-order step failed");
    assert_eq!(
        snap.tpot_decode.count,
        8,
        "every decode response (incl. the failed step) records a TPOT sample"
    );
    assert_eq!(snap.ttft_prefill.count, 7, "the interleaved stateless prefills record TTFT");
}

/// AOT PJRT artifacts have static shapes, so neither sharded path can
/// execute there — the server must refuse explicitly (with a
/// request-kind-specific message) rather than truncate query rows or
/// corrupt a session. The refusal happens at dispatch, before any
/// engine loads, so the bogus artifact dir is never touched.
#[cfg(feature = "pjrt")]
#[test]
fn pjrt_backend_refuses_sharded_decode_explicitly() {
    let router = Router::new(vec![Variant {
        name: "attn_pjrt".into(),
        model: "tiny".into(),
        max_t: 128,
        s: 512,
    }]);
    let backend = Backend::Pjrt {
        artifact_dir: std::path::PathBuf::from("/nonexistent-artifacts"),
        contexts: BTreeMap::new(),
    };
    let srv = Server::start(
        router,
        backend,
        ServerConfig { batcher: BatcherConfig { target_t: 16, max_wait_s: 1e-3 }, workers: 1 },
    );
    let d = 8;
    let (q, k, v) = (Mat::zeros(48, d), Mat::zeros(48, d), Mat::zeros(48, d));
    let rx = srv.submit(Request::decode(1, "tiny", 3, q, k, v, 48, 0.0)).unwrap();
    let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
    assert!(
        resp.variant.contains("sharded decode is not supported on the PJRT backend"),
        "expected the explicit decode refusal, got {:?}",
        resp.variant
    );
    assert!(resp.output.is_none());
    let rx = srv.submit(Request::new(2, "tiny", 48, 256, 0.0)).unwrap();
    let resp = rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
    assert!(
        resp.variant.contains("sharded prefill is not supported on the PJRT backend"),
        "expected the explicit prefill refusal, got {:?}",
        resp.variant
    );
    let snap = srv.shutdown();
    assert_eq!(snap.failed, 2, "both refusals surface as counted failures, not silence");
}

#[test]
fn scheduler_throughput_with_many_batches() {
    // The OoO scheduler drains an LTPP burst completely and issues
    // every tile exactly once.
    let mut s = TiledScheduler::new();
    for b in 0..50u64 {
        s.admit(b, 4, b as f64);
    }
    let mut done = Vec::new();
    let mut last_stage: Option<Stage> = None;
    while let Some(job) = s.issue(last_stage) {
        last_stage = Some(job.stage);
        s.complete(&job);
        done.extend(s.take_done());
    }
    assert_eq!(done.len(), 50);
    assert_eq!(s.issued(), 50 * 4 * 4);
}
