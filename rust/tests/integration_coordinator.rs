//! Integration: the serving stack (router → batcher → scheduler →
//! backend) under load, with the simulated and native backends.

use star::attention::{masked_attention_oracle, AttnInputs};
use star::config::AccelConfig;
use star::coordinator::{
    Backend, BatcherConfig, Request, Router, Server, ServerConfig, Stage, TiledScheduler, Variant,
};
use star::pipeline::{PipelineConfig, PipelineInputs, SparseAttentionPipeline};
use star::sim::dram::DramChannel;
use star::sim::pipeline::FeatureSet;
use star::tensor::Mat;
use star::util::Rng;
use std::collections::BTreeMap;

fn server(target_t: usize, workers: usize) -> Server {
    let router = Router::new(vec![
        Variant { name: "attn_small".into(), model: "tiny".into(), max_t: 128, s: 512 },
        Variant { name: "attn_big".into(), model: "tiny".into(), max_t: 128, s: 4096 },
    ]);
    let backend = Backend::Sim {
        feats: FeatureSet::star(),
        accel: AccelConfig::default(),
        dram: DramChannel::accel_256(),
        d: 64,
        h: 768,
        keep: 0.2,
        time_scale: 0.0,
    };
    Server::start(
        router,
        backend,
        ServerConfig { batcher: BatcherConfig { target_t, max_wait_s: 1e-3 }, workers },
    )
}

#[test]
fn hundred_requests_across_buckets() {
    let srv = server(64, 4);
    let mut rng = Rng::new(5);
    let mut rxs = Vec::new();
    for id in 0..100u64 {
        let s = if rng.chance(0.5) { 256 } else { 2048 };
        rxs.push(srv.submit(Request::new(id, "tiny", 8, s, 0.0)).unwrap());
    }
    let mut small = 0;
    let mut big = 0;
    for rx in rxs {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        match resp.variant.as_str() {
            "attn_small" => small += 1,
            "attn_big" => big += 1,
            other => panic!("unexpected variant {other}"),
        }
    }
    assert_eq!(small + big, 100);
    assert!(small > 10 && big > 10, "both buckets used: {small}/{big}");
    let snap = srv.shutdown();
    assert_eq!(snap.requests, 100);
    assert!(snap.mean_batch_rows > 8.0, "batching actually batched: {}", snap.mean_batch_rows);
}

#[test]
fn shutdown_flushes_everything() {
    let srv = server(10_000, 1); // never fills naturally
    let mut rxs = Vec::new();
    for id in 0..5u64 {
        rxs.push(srv.submit(Request::new(id, "tiny", 4, 256, 0.0)).unwrap());
    }
    // Don't wait for the timeout: shut down immediately.
    let snap = srv.shutdown();
    assert_eq!(snap.requests, 5);
    for rx in rxs {
        assert!(rx.try_recv().is_ok(), "response delivered on shutdown flush");
    }
}

#[test]
fn native_backend_round_trip_matches_inline_pipeline() {
    // End to end through router → batcher → workers, the server must
    // return exactly what an inline pipeline run over the same Q and KV
    // context computes — real sparse attention, served.
    let (s, d) = (512usize, 32usize);
    let mut rng = Rng::new(77);
    let kctx = Mat::randn(s, d, 1.0, &mut rng);
    let vctx = Mat::randn(s, d, 1.0, &mut rng);
    let pipeline = PipelineConfig::star().with_threads(1);
    let mut contexts = BTreeMap::new();
    contexts.insert("attn_native".to_string(), (kctx.clone(), vctx.clone()));
    let router = Router::new(vec![Variant {
        name: "attn_native".into(),
        model: "tiny".into(),
        max_t: 128,
        s,
    }]);
    let srv = Server::start(
        router,
        Backend::Native { pipeline, contexts },
        // target_t = 1 row seals a batch per request, so each response is
        // comparable to an inline single-request pipeline run.
        ServerConfig { batcher: BatcherConfig { target_t: 1, max_wait_s: 1e-4 }, workers: 2 },
    );
    let mut submitted = Vec::new();
    for id in 0..8u64 {
        let t = 4 + (id as usize % 3) * 2;
        let q = Mat::randn(t, d, 1.0, &mut rng);
        let mut req = Request::new(id, "tiny", t, s, 0.0);
        req.q = Some(q.clone());
        submitted.push((q, srv.submit(req).unwrap()));
    }
    for (q, rx) in submitted {
        let resp = rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        assert_eq!(resp.variant, "attn_native");
        let out = resp.output.expect("native round trip returns outputs");
        let inline = SparseAttentionPipeline::new(PipelineConfig::star().with_threads(1))
            .run(&PipelineInputs::qkv(&q, &kctx, &vctx));
        assert_eq!(
            out.max_abs_diff(&inline.out),
            0.0,
            "served output must equal the inline pipeline result"
        );
        // And that result is the exact softmax over the pipeline's selection.
        let inp = AttnInputs::new(&q, &kctx, &vctx);
        let oracle = masked_attention_oracle(&inp, &inline.selection);
        assert!(out.max_abs_diff(&oracle) < 1e-4);
    }
    let snap = srv.shutdown();
    assert_eq!(snap.requests, 8);
    assert!(snap.stage_predict_s > 0.0 && snap.stage_formal_s > 0.0, "per-stage metrics recorded");
    assert_eq!(snap.rejected, 0);
}

#[test]
fn scheduler_throughput_with_many_batches() {
    // The OoO scheduler drains an LTPP burst completely and issues
    // every tile exactly once.
    let mut s = TiledScheduler::new();
    for b in 0..50u64 {
        s.admit(b, 4, b as f64);
    }
    let mut done = Vec::new();
    let mut last_stage: Option<Stage> = None;
    while let Some(job) = s.issue(last_stage) {
        last_stage = Some(job.stage);
        s.complete(&job);
        done.extend(s.take_done());
    }
    assert_eq!(done.len(), 50);
    assert_eq!(s.issued(), 50 * 4 * 4);
}
