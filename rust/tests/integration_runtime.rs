//! Integration: PJRT runtime × AOT artifacts.
//!
//! Compiled only under the `pjrt` feature (the runtime needs the `xla`
//! crate, absent offline). These tests additionally need `make artifacts`
//! to have run; they skip (pass trivially with a notice) when the
//! artifact directory is absent so `cargo test` works in a fresh
//! checkout.
#![cfg(feature = "pjrt")]

use star::runtime::engine::artifacts_available;
use star::runtime::{Engine, Manifest};
use star::tensor::Mat;
use star::util::Rng;
use std::path::Path;

fn dir() -> std::path::PathBuf {
    star::runtime::manifest::default_dir()
}

fn skip() -> bool {
    if artifacts_available(&dir()) {
        false
    } else {
        eprintln!("SKIP: no artifacts at {:?} (run `make artifacts`)", dir());
        true
    }
}

#[test]
fn manifest_lists_expected_entries() {
    if skip() {
        return;
    }
    let m = Manifest::load(&dir()).unwrap();
    for name in [
        "sparse_attention",
        "sparse_attention_tiny",
        "dense_attention_tiny",
        "transformer_block",
    ] {
        assert!(m.get(name).is_some(), "missing artifact {name}");
        assert!(m.hlo_path(&dir(), name).unwrap().is_file());
    }
}

#[test]
fn dense_attention_artifact_matches_oracle() {
    if skip() {
        return;
    }
    let engine = Engine::load_dir(&dir()).unwrap();
    let entry = engine.get("dense_attention_tiny").unwrap();
    let (t, d) = (entry.entry.inputs[0][0], entry.entry.inputs[0][1]);
    let s = entry.entry.inputs[1][0];
    let mut rng = Rng::new(7);
    let q = Mat::randn(t, d, 1.0, &mut rng);
    let k = Mat::randn(s, d, 1.0, &mut rng);
    let v = Mat::randn(s, d, 1.0, &mut rng);
    let out = engine.run("dense_attention_tiny", &[q.clone(), k.clone(), v.clone()]).unwrap();
    assert_eq!(out.len(), 1);
    let got = &out[0];
    assert_eq!((got.rows, got.cols), (t, d));
    // Oracle: rust-side dense attention.
    let inp = star::attention::AttnInputs::new(&q, &k, &v);
    let mut c = star::arith::OpCounter::new();
    let want = star::attention::dense_attention(&inp, usize::MAX, &mut c);
    let err = got.max_abs_diff(&want);
    assert!(err < 1e-4, "PJRT vs rust oracle diff {err}");
}

#[test]
fn sparse_attention_artifact_close_to_dense_oracle() {
    if skip() {
        return;
    }
    let engine = Engine::load_dir(&dir()).unwrap();
    let entry = engine.get("sparse_attention_tiny").unwrap();
    let (t, d) = (entry.entry.inputs[0][0], entry.entry.inputs[0][1]);
    let s = entry.entry.inputs[1][0];
    let mut rng = Rng::new(11);
    let q = Mat::randn(t, d, 1.0, &mut rng);
    let k = Mat::randn(s, d, 1.0, &mut rng);
    let v = Mat::randn(s, d, 1.0, &mut rng);
    let out = engine.run("sparse_attention_tiny", &[q.clone(), k.clone(), v.clone()]).unwrap();
    let got = &out[0];
    assert_eq!((got.rows, got.cols), (t, d));
    for x in &got.data {
        assert!(x.is_finite());
    }
    // Top-25% sparse output tracks the dense oracle only loosely on
    // i.i.d. Gaussian data (no sparsity structure to exploit — the
    // worst case). Tight bounds vs the exact masked oracle live in
    // pytest; here we check the artifact is sane end to end.
    let inp = star::attention::AttnInputs::new(&q, &k, &v);
    let mut c = star::arith::OpCounter::new();
    let dense = star::attention::dense_attention(&inp, usize::MAX, &mut c);
    let rel = got.rel_err(&dense);
    assert!(rel < 0.9, "sparse vs dense rel err {rel}");
}

#[test]
fn transformer_block_artifact_runs() {
    if skip() {
        return;
    }
    let engine = Engine::load_dir(&dir()).unwrap();
    let entry = engine.get("transformer_block").unwrap();
    let mut rng = Rng::new(13);
    let inputs: Vec<Mat> = entry
        .entry
        .inputs
        .iter()
        .map(|shape| Mat::randn(shape[0], shape[1], 0.3, &mut rng))
        .collect();
    let out = engine.run("transformer_block", &inputs).unwrap();
    assert_eq!(out[0].rows, entry.entry.inputs[0][0]);
    assert_eq!(out[0].cols, entry.entry.inputs[0][1]);
    for x in &out[0].data {
        assert!(x.is_finite());
    }
}

#[test]
fn engine_rejects_bad_inputs() {
    if skip() {
        return;
    }
    let engine = Engine::load_dir(&dir()).unwrap();
    assert!(engine.run("no_such_entry", &[]).is_err());
    let bad = Mat::zeros(2, 2);
    assert!(engine.run("dense_attention_tiny", &[bad]).is_err());
}

#[test]
fn missing_dir_is_an_error_not_a_panic() {
    let missing = Path::new("/nonexistent/star-artifacts");
    assert!(!artifacts_available(missing));
    assert!(Engine::load_dir(missing).is_err());
    assert!(Manifest::load(missing).is_err());
}
