//! Property: byte-traffic counting is invisible to the math, free of
//! heap traffic on the warm hot path, and exactly reproducible.
//!
//! The traffic counters (`star::obs::traffic`) live inside the pooled
//! [`star::pipeline::TileWorkspace`] and are bumped with pure integer
//! arithmetic inside the metered stage cores, so three contracts hold:
//!
//! 1. **Bit-invisibility.** Outputs, selections and stalls of all three
//!    execution paths (batch prefill, autoregressive decode,
//!    sequence-sharded prefill) are identical with counting off and on.
//! 2. **Zero-allocation counting.** This binary installs the counting
//!    allocator; warm counted runs must meter zero hot-path allocations.
//! 3. **Exact reproducibility.** The measured byte counters are pure
//!    functions of shape + selection: every field matches exactly
//!    between thread counts (the work-stealing schedule moves tiles
//!    between workers but cannot change what they read or write) and
//!    between repeated runs. Only the scheduler stats
//!    (`SchedStats`) may differ run-to-run.
//!
//! The counted phase deliberately never disables counting afterwards:
//! the flag is process-global and this is the one test binary that
//! flips it (tests within a binary share the process). The disabled
//! baseline therefore runs *first*, inside the single test that
//! touches the flag.

#[global_allocator]
static ALLOC: star::util::allocmeter::CountingAllocator =
    star::util::allocmeter::CountingAllocator;

use star::kvcache::{SessionConfig, SessionStore};
use star::obs::TrafficCounter;
use star::pipeline::{
    PipelineConfig, PipelineInputs, ShardedPipeline, SparseAttentionPipeline, WorkspacePool,
};
use star::tensor::Mat;
use star::util::{allocmeter, Rng};

fn mats(t: usize, s: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
    let mut rng = Rng::new(seed);
    (
        Mat::randn(t, d, 1.0, &mut rng),
        Mat::randn(s, d, 1.0, &mut rng),
        Mat::randn(s, d, 1.0, &mut rng),
    )
}

fn sub(m: &Mat, lo: usize, hi: usize) -> Mat {
    Mat::from_fn(hi - lo, m.cols, |i, j| m.at(lo + i, j))
}

#[test]
fn counting_allocator_is_live_in_this_binary() {
    let a0 = allocmeter::thread_allocs();
    let v: Vec<u64> = Vec::with_capacity(64);
    assert!(allocmeter::thread_allocs() > a0, "allocation meter must count");
    assert!(allocmeter::installed());
    drop(v);
}

/// One decode session (8-token prefill chunk + 8 single-token steps) on
/// a warm pool: per-step outputs, selections, the summed traffic and
/// the hot-path alloc sum of the steps.
fn decode_session(
    cfg: PipelineConfig,
    q: &Mat,
    k: &Mat,
    v: &Mat,
    pool: &WorkspacePool,
) -> (Vec<Mat>, Vec<star::attention::Selection>, TrafficCounter, u64) {
    let d = q.cols;
    let pipe = SparseAttentionPipeline::new(cfg);
    let mut store = SessionStore::new(SessionConfig::for_pipeline(&cfg, d, 0));
    let mut traffic = TrafficCounter::new();
    let r0 = pipe
        .decode_step_pooled(&mut store, 1, &sub(q, 0, 8), &sub(k, 0, 8), &sub(v, 0, 8), pool)
        .expect("prefill chunk");
    traffic.merge(&r0.traffic);
    let (mut outs, mut sels, mut allocs) = (Vec::new(), Vec::new(), 0u64);
    for lo in 8..16 {
        let r = pipe
            .decode_step_pooled(
                &mut store,
                1,
                &sub(q, lo, lo + 1),
                &sub(k, lo, lo + 1),
                &sub(v, lo, lo + 1),
                pool,
            )
            .expect("decode step");
        allocs += r.hot_path_allocs;
        traffic.merge(&r.traffic);
        outs.push(r.out);
        sels.push(r.selection);
    }
    (outs, sels, traffic, allocs)
}

#[test]
fn traffic_counting_is_bit_invisible_allocation_free_and_reproducible() {
    let cfg = PipelineConfig::star().with_keep(0.25).with_tile(8).with_threads(1);
    let (q, k, v) = mats(24, 128, 16, 42);
    let inputs = PipelineInputs::qkv(&q, &k, &v);
    let pipe = SparseAttentionPipeline::new(cfg);
    let sharded = ShardedPipeline::new(cfg, 2);

    // ---- Baseline, counting disabled (the process default; this is
    // the only test in this binary that flips the flag). ----
    assert!(!star::obs::traffic::enabled(), "counting must start disabled in this binary");
    let pool_off = WorkspacePool::new();
    let base_prefill = pipe.run_pooled(&inputs, &pool_off);
    let base_sharded = sharded.run_pooled(&inputs, &pool_off);
    let (base_outs, base_sels, base_traffic, _) = decode_session(cfg, &q, &k, &v, &pool_off);
    assert_eq!(base_prefill.traffic, TrafficCounter::default(), "off: prefill must not count");
    assert_eq!(base_sharded.traffic, TrafficCounter::default(), "off: sharded must not count");
    assert_eq!(base_traffic, TrafficCounter::default(), "off: decode must not count");

    // ---- Counted: same workload on a fresh pool. First passes warm
    // the workspaces (allocs uncounted); second passes measure. ----
    star::obs::traffic::set_enabled(true);
    let pool_on = WorkspacePool::new();
    pipe.run_pooled(&inputs, &pool_on);
    sharded.run_pooled(&inputs, &pool_on);
    let counted_prefill = pipe.run_pooled(&inputs, &pool_on);
    let counted_sharded = sharded.run_pooled(&inputs, &pool_on);
    let (counted_outs, counted_sels, counted_decode, decode_allocs) =
        decode_session(cfg, &q, &k, &v, &pool_on);

    // 1. Bit-invisibility.
    assert_eq!(counted_prefill.out.max_abs_diff(&base_prefill.out), 0.0, "prefill output drift");
    assert_eq!(counted_prefill.selection, base_prefill.selection, "prefill selection drift");
    assert_eq!(counted_prefill.stalls, base_prefill.stalls, "prefill stall drift");
    assert_eq!(counted_sharded.out.max_abs_diff(&base_sharded.out), 0.0, "sharded output drift");
    assert_eq!(counted_sharded.selection, base_sharded.selection, "sharded selection drift");
    assert_eq!(counted_outs.len(), base_outs.len());
    for (i, (c, b)) in counted_outs.iter().zip(&base_outs).enumerate() {
        assert_eq!(c.max_abs_diff(b), 0.0, "decode step {i} output drift");
    }
    assert_eq!(counted_sels, base_sels, "decode selection drift");

    // 2. Counting actually counted, without touching the heap in the
    // metered stage cores.
    assert!(counted_prefill.traffic.total_bytes() > 0, "on: prefill counted nothing");
    assert!(counted_sharded.traffic.total_bytes() > 0, "on: sharded counted nothing");
    assert!(counted_decode.total_bytes() > 0, "on: decode counted nothing");
    assert!(counted_sharded.traffic.ring_payload_bytes > 0, "sharded ring payload uncounted");
    assert_eq!(counted_prefill.traffic.ring_payload_bytes, 0, "single-core prefill has no ring");
    assert!(counted_decode.cache_append_bytes > 0, "decode cache appends uncounted");
    assert_eq!(counted_prefill.hot_path_allocs, 0, "counted warm prefill allocated");
    assert_eq!(counted_sharded.hot_path_allocs, 0, "counted warm sharded run allocated");
    assert_eq!(decode_allocs, 0, "counted warm decode steps allocated");

    // 3. Exact reproducibility: every byte field is a pure function of
    // shape + selection. (a) Same run repeated — identical.
    let again = pipe.run_pooled(&inputs, &pool_on);
    assert_eq!(again.traffic, counted_prefill.traffic, "prefill bytes drift run-to-run");
    // (b) Different thread count — the work-stealing schedule changes,
    // the bytes must not. (Scheduler stats may legitimately differ.)
    let cfg4 = PipelineConfig::star().with_keep(0.25).with_tile(8).with_threads(4);
    let pipe4 = SparseAttentionPipeline::new(cfg4);
    let pool4 = WorkspacePool::new();
    pipe4.run_pooled(&inputs, &pool4);
    let counted4 = pipe4.run_pooled(&inputs, &pool4);
    assert_eq!(counted4.out.max_abs_diff(&counted_prefill.out), 0.0, "thread-count output drift");
    assert_eq!(counted4.traffic, counted_prefill.traffic, "bytes differ across thread counts");
    // (c) Sharded likewise reproduces, ring payload included.
    let again_sharded = sharded.run_pooled(&inputs, &pool_on);
    assert_eq!(again_sharded.traffic, counted_sharded.traffic, "sharded bytes drift run-to-run");
    // (d) A decode session re-run from scratch reproduces exactly.
    let (_, _, decode_again, _) = decode_session(cfg, &q, &k, &v, &pool_on);
    assert_eq!(decode_again, counted_decode, "decode bytes drift session-to-session");

    // The DRAM-class split is consistent: the counter classes partition
    // the total.
    for (name, t) in [
        ("prefill", &counted_prefill.traffic),
        ("sharded", &counted_sharded.traffic),
        ("decode", &counted_decode),
    ] {
        assert_eq!(
            t.total_bytes(),
            t.dram_class_bytes()
                + t.sram_class_bytes()
                + t.ring_payload_bytes
                + t.cache_append_bytes
                + t.cache_remat_bytes,
            "{name}: classes must partition the total"
        );
    }
}
