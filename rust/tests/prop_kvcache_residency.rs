//! Properties of the production-residency KV-cache: page-granular
//! eviction, copy-on-write prefix sharing and quantized-only residency
//! (ISSUE 10 acceptance).
//!
//! 1. Sessions that share a prompt prefix stay **bit-identical** to
//!    their solo unbounded runs through divergence (copy-on-write
//!    splits) and eviction storms — sharing and page-granular
//!    replacement are pure optimizations.
//! 2. The PR-3 eviction/re-materialization parity property holds at
//!    **every pool size**, not just the one the seed test picked —
//!    page-granular eviction strictly generalizes whole-session LRU.
//! 3. Warm decode under cache pressure meters **zero hot-path
//!    allocations**: eviction and re-materialization run outside the
//!    metered stage cores (this binary installs the counting
//!    allocator, so the meter is live).
//! 4. Refcounts never leak: dropping every session returns the pool to
//!    empty — bytes, pages and registry all reach zero.
//!
//! Plus the quantized-only residency contract: selection bit-identical
//! to the exact mode, outputs within the dequant scale, and the
//! quantized store is bit-stable against its own unbounded run across
//! eviction (re-quantizing the same history reproduces the same
//! resident integers).

#[global_allocator]
static ALLOC: star::util::allocmeter::CountingAllocator =
    star::util::allocmeter::CountingAllocator;

use star::attention::Selection;
use star::kvcache::{ResidencyMode, SessionConfig, SessionStore};
use star::pipeline::{PipelineConfig, SparseAttentionPipeline, WorkspacePool};
use star::tensor::Mat;
use star::util::{allocmeter, Rng};

fn toks(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
    let mut rng = Rng::new(seed);
    (
        Mat::randn(n, d, 1.0, &mut rng),
        Mat::randn(n, d, 1.0, &mut rng),
        Mat::randn(n, d, 1.0, &mut rng),
    )
}

fn sub(m: &Mat, lo: usize, hi: usize) -> Mat {
    Mat::from_fn(hi - lo, m.cols, |i, j| m.at(lo + i, j))
}

fn vcat(a: &Mat, b: &Mat) -> Mat {
    Mat::from_fn(a.rows + b.rows, a.cols, |i, j| {
        if i < a.rows {
            a.at(i, j)
        } else {
            b.at(i - a.rows, j)
        }
    })
}

/// The shared-prefix fleet: `D`=8, 8-token pages (tile 8), a 20-token
/// common prefix (2.5 pages — divergence lands mid-page, exercising the
/// copy-on-write split, not just boundary attaches) and 20 distinct
/// continuation tokens per session (5 pages per session total).
const D: usize = 8;
const PREFIX: usize = 20;
const CONT: usize = 20;
const SESSIONS: usize = 3;
/// A pool that fits any one session (5 pages) but not the fleet's ~11
/// physical pages — every round-robin cycle evicts and rebuilds.
const STORM_POOL: usize = 7;

fn fleet_cfg() -> PipelineConfig {
    PipelineConfig::star().with_keep(0.3).with_tile(8).with_threads(1)
}

fn fleet_inputs() -> ((Mat, Mat, Mat), Vec<(Mat, Mat, Mat)>) {
    let prefix = toks(PREFIX, D, 42);
    let conts = (0..SESSIONS).map(|i| toks(CONT, D, 100 + i as u64)).collect();
    (prefix, conts)
}

/// Run the fleet through one store: every session appends the identical
/// prefix chunk, then the sessions decode `chunk`-token continuations
/// round-robin (the adversarial pattern for whole-session LRU). Returns
/// each session's concatenated outputs/selections plus the store.
fn fleet_run(
    cfg: &PipelineConfig,
    capacity_pages: usize,
    mode: ResidencyMode,
    prefix: &(Mat, Mat, Mat),
    conts: &[(Mat, Mat, Mat)],
    chunk: usize,
) -> (Vec<(Mat, Selection)>, SessionStore) {
    let pipe = SparseAttentionPipeline::new(*cfg);
    let scfg = SessionConfig::for_pipeline(cfg, D, capacity_pages).with_residency(mode);
    let mut store = SessionStore::new(scfg);
    let n = PREFIX + CONT;
    let mut outs: Vec<Mat> = (0..conts.len()).map(|_| Mat::zeros(n, D)).collect();
    let mut sels: Vec<Vec<Vec<usize>>> = vec![Vec::new(); conts.len()];
    let (pq, pk, pv) = prefix;
    for s in 0..conts.len() {
        let r = pipe.decode_step(&mut store, s as u64 + 1, pq, pk, pv).expect("prefix");
        for i in 0..PREFIX {
            outs[s].row_mut(i).copy_from_slice(r.out.row(i));
        }
        sels[s].extend(r.selection.rows);
    }
    let mut at = 0usize;
    while at < CONT {
        let hi = (at + chunk).min(CONT);
        for (s, (cq, ck, cv)) in conts.iter().enumerate() {
            let r = pipe
                .decode_step(
                    &mut store,
                    s as u64 + 1,
                    &sub(cq, at, hi),
                    &sub(ck, at, hi),
                    &sub(cv, at, hi),
                )
                .expect("continuation step");
            for i in 0..hi - at {
                outs[s].row_mut(PREFIX + at + i).copy_from_slice(r.out.row(i));
            }
            sels[s].extend(r.selection.rows);
        }
        at = hi;
    }
    let per_session = outs
        .into_iter()
        .zip(sels)
        .map(|(o, rows)| (o, Selection { rows }))
        .collect();
    (per_session, store)
}

/// Solo unbounded reference for one session's full token stream.
fn solo(cfg: &PipelineConfig, q: &Mat, k: &Mat, v: &Mat) -> (Mat, Selection) {
    let pipe = SparseAttentionPipeline::new(*cfg);
    let mut store = SessionStore::new(SessionConfig::for_pipeline(cfg, D, 0));
    let r = pipe.decode_step(&mut store, 1, q, k, v).expect("solo run");
    (r.out, r.selection)
}

fn assert_bit_identical(
    (got_out, got_sel): &(Mat, Selection),
    (want_out, want_sel): &(Mat, Selection),
    what: &str,
) {
    assert_eq!(got_sel, want_sel, "{what}: selection drift");
    assert_eq!(got_out.max_abs_diff(want_out), 0.0, "{what}: output drift");
}

/// Property 1: shared prefixes + divergence + eviction storm ⇒ every
/// session still matches its solo unbounded run bit for bit.
#[test]
fn shared_prefix_fleet_is_bit_identical_through_divergence_and_eviction() {
    let cfg = fleet_cfg();
    let (prefix, conts) = fleet_inputs();
    let refs: Vec<(Mat, Selection)> = conts
        .iter()
        .map(|(cq, ck, cv)| {
            solo(&cfg, &vcat(&prefix.0, cq), &vcat(&prefix.1, ck), &vcat(&prefix.2, cv))
        })
        .collect();
    for capacity in [0usize, STORM_POOL] {
        let (got, store) = fleet_run(&cfg, capacity, ResidencyMode::Exact, &prefix, &conts, 2);
        let stats = store.stats();
        assert!(stats.pages_shared > 0, "cap={capacity}: prefix pages must be shared");
        assert!(stats.cow_splits > 0, "cap={capacity}: mid-page divergence must split");
        if capacity > 0 {
            assert!(stats.pages_evicted > 0, "the storm pool was sized to evict");
            assert!(stats.pages_rematerialized > 0, "evicted pages were rebuilt");
        } else {
            assert_eq!(stats.pages_evicted, 0, "unbounded pool never evicts");
        }
        for (s, (got_s, want_s)) in got.iter().zip(&refs).enumerate() {
            assert_bit_identical(got_s, want_s, &format!("cap={capacity} session={s}"));
        }
    }
}

/// Property 2: the PR-3 whole-session eviction/remat parity property
/// holds at **every** pool size that admits the sessions at all.
#[test]
fn eviction_parity_holds_at_every_pool_size() {
    let n = 40usize; // 5 pages of 8 per session
    let (qa, ka, va) = toks(n, D, 5);
    let (qb, kb, vb) = toks(n, D, 6);
    let cfg = fleet_cfg();
    let full_a = solo(&cfg, &qa, &ka, &va);
    let full_b = solo(&cfg, &qb, &kb, &vb);
    let pipe = SparseAttentionPipeline::new(cfg);
    // 5 pages is the single-session minimum; 10 fits both; 0 unbounded.
    for capacity in [5usize, 6, 7, 8, 9, 10, 0] {
        let mut store = SessionStore::new(SessionConfig::for_pipeline(&cfg, D, capacity));
        let mut out_a = Mat::zeros(n, D);
        let mut out_b = Mat::zeros(n, D);
        let (mut sel_a, mut sel_b) = (Vec::new(), Vec::new());
        for start in (0..n).step_by(4) {
            let end = start + 4;
            let ra = pipe
                .decode_step(&mut store, 1, &sub(&qa, start, end), &sub(&ka, start, end), &sub(&va, start, end))
                .expect("session A step");
            for i in 0..4 {
                out_a.row_mut(start + i).copy_from_slice(ra.out.row(i));
            }
            sel_a.extend(ra.selection.rows);
            let rb = pipe
                .decode_step(&mut store, 2, &sub(&qb, start, end), &sub(&kb, start, end), &sub(&vb, start, end))
                .expect("session B step");
            for i in 0..4 {
                out_b.row_mut(start + i).copy_from_slice(rb.out.row(i));
            }
            sel_b.extend(rb.selection.rows);
        }
        let stats = store.stats();
        if capacity > 0 && capacity < 10 {
            assert!(
                stats.pages_evicted > 0,
                "cap={capacity} cannot hold both sessions without evicting"
            );
            assert!(stats.pages_rematerialized > 0, "cap={capacity} must rebuild");
        }
        assert_bit_identical(
            &(out_a, Selection { rows: sel_a }),
            &full_a,
            &format!("cap={capacity} session A"),
        );
        assert_bit_identical(
            &(out_b, Selection { rows: sel_b }),
            &full_b,
            &format!("cap={capacity} session B"),
        );
    }
}

/// Property 3: decode under eviction pressure allocates nothing inside
/// the metered stage cores — re-materialization and copy-on-write
/// splits happen outside the hot path.
#[test]
fn warm_decode_under_pressure_allocates_nothing() {
    assert!(allocmeter::installed(), "this binary installs the counting allocator");
    let cfg = fleet_cfg();
    let (prefix, conts) = fleet_inputs();
    let pipe = SparseAttentionPipeline::new(cfg);
    let scfg = SessionConfig::for_pipeline(&cfg, D, STORM_POOL);
    let mut store = SessionStore::new(scfg);
    let pool = WorkspacePool::new();
    let (pq, pk, pv) = &prefix;
    for s in 0..conts.len() {
        pipe.decode_step_pooled(&mut store, s as u64 + 1, pq, pk, pv, &pool).expect("prefix");
    }
    let mut hot = 0u64;
    for at in 0..CONT {
        for (s, (cq, ck, cv)) in conts.iter().enumerate() {
            let r = pipe
                .decode_step_pooled(
                    &mut store,
                    s as u64 + 1,
                    &sub(cq, at, at + 1),
                    &sub(ck, at, at + 1),
                    &sub(cv, at, at + 1),
                    &pool,
                )
                .expect("pressured step");
            hot += r.hot_path_allocs;
        }
    }
    let stats = store.stats();
    assert!(stats.pages_evicted > 0, "the pool was sized to force eviction churn");
    assert!(stats.pages_rematerialized > 0, "churn must rebuild pages");
    assert_eq!(hot, 0, "decode hot path allocated under cache pressure");
}

/// Property 4: refcounts never leak — dropping every session empties
/// the pool completely, shared pages included.
#[test]
fn removing_all_sessions_empties_the_pool() {
    let cfg = fleet_cfg();
    let (prefix, conts) = fleet_inputs();
    let (_, mut store) = fleet_run(&cfg, STORM_POOL, ResidencyMode::Exact, &prefix, &conts, 2);
    let before = store.residency();
    assert!(before.resident_pages > 0 && before.resident_bytes > 0);
    assert_eq!(before.sessions, SESSIONS);
    for s in 0..SESSIONS {
        store.remove(s as u64 + 1);
        let r = store.residency();
        assert_eq!(r.sessions, SESSIONS - s - 1);
    }
    let after = store.residency();
    assert_eq!(after.resident_pages, 0, "refcount leak: pages survived every owner");
    assert_eq!(after.resident_bytes, 0);
    assert_eq!(after.shared_pages, 0);
    assert_eq!(after.logical_tokens, 0);
    // The emptied pool is fully reusable: a fresh session round-trips.
    let (q, k, v) = toks(16, D, 777);
    let got = {
        let pipe = SparseAttentionPipeline::new(cfg);
        let r = pipe.decode_step(&mut store, 9, &q, &k, &v).expect("fresh session");
        (r.out, r.selection)
    };
    assert_bit_identical(&got, &solo(&cfg, &q, &k, &v), "post-drain fresh session");
}

/// Quantized-only residency: selection bit-identical to exact mode,
/// outputs within the dequant scale, and bit-stable against its own
/// unbounded run across eviction (re-quantization is deterministic).
#[test]
fn quantized_only_keeps_selection_and_survives_eviction_bit_stably() {
    let cfg = fleet_cfg();
    let (prefix, conts) = fleet_inputs();
    let (exact, _) = fleet_run(&cfg, 0, ResidencyMode::Exact, &prefix, &conts, 2);
    let (quant, qstore) = fleet_run(&cfg, 0, ResidencyMode::QuantizedOnly, &prefix, &conts, 2);
    assert!(qstore.stats().pages_shared > 0, "sharing must work in quantized mode");
    for (s, ((eo, es), (qo, qs))) in exact.iter().zip(&quant).enumerate() {
        assert_eq!(es, qs, "session {s}: quantized residency changed the selection");
        let dev = eo.max_abs_diff(qo) as f64;
        assert!(dev < 0.5, "session {s}: quantized gather deviated {dev}");
    }
    // Eviction storms in quantized mode reproduce the unbounded run bit
    // for bit: re-materialization re-quantizes the same f32 history
    // into the same resident integers and scales.
    let (quant_storm, sstore) =
        fleet_run(&cfg, STORM_POOL, ResidencyMode::QuantizedOnly, &prefix, &conts, 2);
    assert!(sstore.stats().pages_evicted > 0, "the storm pool was sized to evict");
    for (s, (got, want)) in quant_storm.iter().zip(&quant).enumerate() {
        assert_bit_identical(got, want, &format!("quantized storm session={s}"));
    }
    // And the quantized pool is measurably smaller per resident token.
    let er = {
        let (_, estore) = fleet_run(&cfg, 0, ResidencyMode::Exact, &prefix, &conts, 2);
        estore.residency()
    };
    let qr = qstore.residency();
    assert_eq!(er.resident_pages, qr.resident_pages, "mode must not change paging");
    assert!(
        er.resident_bytes as f64 >= 3.0 * qr.resident_bytes as f64,
        "quantized-only must shrink resident bytes ≥3×: exact={} quantized={}",
        er.resident_bytes,
        qr.resident_bytes
    );
}
