//! Integration: MRCA schedules drive the DRAttention dataflow on the
//! mesh, and the spatial simulator's orderings hold across sizes.

use star::config::SpatialConfig;
use star::spatial::mesh::{Coord, Mesh};
use star::spatial::mrca::{mrca_schedule, verify_schedule};
use star::spatial::sim::{spatial_run, CoreKind, Dataflow};

/// MRCA is complete and bounded for every row length used by any mesh
/// from 2×2 to 8×8.
#[test]
fn mrca_complete_for_all_practical_meshes() {
    for n in 2..=8 {
        let sched = mrca_schedule(n);
        let chk = verify_schedule(n, &sched).unwrap();
        assert!(chk.complete, "N={n}");
        assert!(chk.max_resident <= 3, "N={n} resident {}", chk.max_resident);
    }
}

/// The mesh routes MRCA sends as single hops (that is the point).
#[test]
fn mrca_sends_are_single_hop_on_mesh() {
    let mesh = Mesh::from_config(&SpatialConfig::mesh5x5());
    for st in mrca_schedule(5) {
        for s in &st.sends {
            let from = mesh.id(Coord { row: 2, col: s.src - 1 });
            let to = mesh.id(Coord { row: 2, col: s.dest - 1 });
            assert_eq!(mesh.xy_route(from, to).len(), 1);
        }
    }
}

/// Dataflow ordering (ring < naive DRA < MRCA DRA in latency) holds on
/// both evaluated mesh sizes and across sequence lengths.
#[test]
fn dataflow_ordering_robust() {
    for cfg in [SpatialConfig::mesh5x5(), SpatialConfig::mesh6x6()] {
        for s in [8192usize, 32768] {
            let ring = spatial_run(&cfg, CoreKind::Star, Dataflow::RingAttention, s, 64, 768, 0.2);
            let dra = spatial_run(&cfg, CoreKind::Star, Dataflow::DrAttentionNaive, s, 64, 768, 0.2);
            let full = spatial_run(&cfg, CoreKind::Star, Dataflow::DrAttentionMrca, s, 64, 768, 0.2);
            assert!(dra.total_s < ring.total_s, "S={s}: dra !< ring");
            assert!(full.total_s <= dra.total_s, "S={s}: mrca !<= dra");
        }
    }
}

/// Throughput grows with mesh size for the MRCA dataflow (sub-linear is
/// allowed: shared DRAM).
#[test]
fn more_cores_do_not_hurt_with_mrca() {
    let s = 32768;
    let mut prev = 0.0;
    for (r, c) in [(2usize, 2usize), (4, 4), (6, 6)] {
        let mut cfg = SpatialConfig::mesh5x5();
        cfg.mesh_rows = r;
        cfg.mesh_cols = c;
        let rep = spatial_run(&cfg, CoreKind::Star, Dataflow::DrAttentionMrca, s, 64, 768, 0.2);
        assert!(rep.eff_gops > prev * 0.8, "{r}x{c}: {} vs prev {}", rep.eff_gops, prev);
        prev = rep.eff_gops;
    }
}
