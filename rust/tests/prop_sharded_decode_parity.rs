//! Property: distributed decode never changes the math.
//!
//! [`star::pipeline::ShardedPipeline::decode_step`] partitions a
//! session's cached pages across N workers (shards propose candidates
//! from their key ranges, the row's home worker merges and runs the
//! unchanged single-core gather + formal core) and must be
//! **bit-identical** to [`star::pipeline::SparseAttentionPipeline::decode_step`]
//! on a twin session — outputs, selections, stall counts, positions —
//! at every shard count, for chunkings that straddle KV page
//! boundaries, across LRU eviction and re-materialization mid-session,
//! and for every top-k engine. This binary installs the counting
//! allocator, so the zero-allocation claim on the warm sharded hot
//! path is a real measurement, not a vacuous one.
//!
//! Kernel-path coverage: the pipeline dispatches on
//! [`star::arith::KernelPath::active`], fixed by the `simd` feature —
//! CI runs this test in both feature legs, so the Scalar and Lanes
//! spellings are each proven against the same contract
//! (`kernel_path_leg_matches_feature_and_keeps_parity` pins the
//! dispatch so a leg cannot silently test the wrong spelling).

#[global_allocator]
static ALLOC: star::util::allocmeter::CountingAllocator =
    star::util::allocmeter::CountingAllocator;

use star::arith::KernelPath;
use star::kvcache::{SessionConfig, SessionStore};
use star::obs::TrafficCounter;
use star::pipeline::{PipelineConfig, ShardedPipeline, SparseAttentionPipeline, WorkspacePool};
use star::sim::pipeline::{PredictKind, TopkKind};
use star::tensor::Mat;
use star::util::{allocmeter, Rng};

/// The acceptance bar's shard counts, including ones that split SADS
/// segment ranges unevenly.
const SHARD_COUNTS: [usize; 5] = [1, 2, 3, 5, 8];

fn toks(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
    let mut rng = Rng::new(seed);
    (
        Mat::randn(n, d, 1.0, &mut rng),
        Mat::randn(n, d, 1.0, &mut rng),
        Mat::randn(n, d, 1.0, &mut rng),
    )
}

fn sub(m: &Mat, lo: usize, hi: usize) -> Mat {
    Mat::from_fn(hi - lo, m.cols, |i, j| m.at(lo + i, j))
}

fn store_for(cfg: &PipelineConfig, d: usize, capacity_pages: usize) -> SessionStore {
    SessionStore::new(SessionConfig::for_pipeline(cfg, d, capacity_pages))
}

/// Feed the same chunk through both pipelines' twin sessions and assert
/// the full bit-identity contract on the pair of reports.
fn step_both(
    sharded: &ShardedPipeline,
    single: &SparseAttentionPipeline,
    st_s: &mut SessionStore,
    st_r: &mut SessionStore,
    q: &Mat,
    k: &Mat,
    v: &Mat,
    tag: &str,
) -> (star::pipeline::ShardedDecodeReport, star::pipeline::DecodeReport) {
    let rs = sharded.decode_step(st_s, 1, q, k, v).expect("sharded decode step");
    let rr = single.decode_step(st_r, 1, q, k, v).expect("single-core decode step");
    assert_eq!(rs.positions, rr.positions, "{tag}: position drift");
    assert_eq!(rs.selection, rr.selection, "{tag}: selection drift");
    assert_eq!(
        rs.out.max_abs_diff(&rr.out),
        0.0,
        "{tag}: output drift (max abs diff {})",
        rs.out.max_abs_diff(&rr.out)
    );
    assert_eq!(rs.stalls, rr.stalls, "{tag}: SU-FA stall drift");
    assert_eq!(rs.union_rows, rr.union_rows, "{tag}: union-row drift");
    assert_eq!(rs.keep_last, rr.keep_last, "{tag}: keep drift");
    (rs, rr)
}

#[test]
fn sharded_decode_bit_identical_across_shard_counts_and_chunkings() {
    let (n, d) = (48usize, 16usize);
    let (q, k, v) = toks(n, d, 11);
    // tile 8 ⇒ KV page size 8; the mixed chunking is chosen so chunks
    // straddle page boundaries (5|9 crosses the first boundary inside
    // one chunk, 11 spans two boundaries, …).
    let cfg = PipelineConfig::star().with_keep(0.25).with_tile(8).with_threads(1);
    let per_token = vec![1usize; n];
    let chunkings: [&[usize]; 3] = [&[48], &[5, 9, 3, 7, 11, 2, 6, 5], &per_token[..]];
    for &w in &SHARD_COUNTS {
        let single = SparseAttentionPipeline::new(cfg);
        let sharded = ShardedPipeline::new(cfg, w);
        for (ci, chunks) in chunkings.iter().enumerate() {
            assert_eq!(chunks.iter().sum::<usize>(), n);
            let (mut st_s, mut st_r) = (store_for(&cfg, d, 0), store_for(&cfg, d, 0));
            let mut at = 0usize;
            for &c in chunks.iter() {
                let tag = format!("shards={w} chunking={ci} at={at}+{c}");
                let (rs, rr) = step_both(
                    &sharded,
                    &single,
                    &mut st_s,
                    &mut st_r,
                    &sub(&q, at, at + c),
                    &sub(&k, at, at + c),
                    &sub(&v, at, at + c),
                    &tag,
                );
                // SADS sharding is comparison-exact: per-stage op
                // counters match the single core, not just the outputs.
                assert_eq!(rs.ops.predict, rr.ops.predict, "{tag}: predict ops");
                assert_eq!(rs.ops.topk, rr.ops.topk, "{tag}: topk ops");
                assert_eq!(rs.ops.kv_gen, rr.ops.kv_gen, "{tag}: kv_gen ops");
                assert_eq!(rs.ops.formal, rr.ops.formal, "{tag}: formal ops");
                assert_eq!(
                    rs.rho_mean.to_bits(),
                    rr.rho_mean.to_bits(),
                    "{tag}: rho drift ({} vs {})",
                    rs.rho_mean,
                    rr.rho_mean
                );
                at += c;
            }
        }
    }
}

#[test]
fn every_topk_engine_matches_across_shard_counts() {
    // The distributed merge has one arm per engine family: SADS
    // (segment-winner lists), Vanilla/Threshold (exact candidate
    // merge), and None (the home selects everything; shards are idle).
    // Op counters are asserted only for SADS (above): the exact
    // engines' partial top-k passes legitimately count differently.
    let (n, d) = (36usize, 16usize);
    let (q, k, v) = toks(n, d, 23);
    let engines: Vec<(&str, PipelineConfig)> = vec![
        (
            "vanilla_lowbit",
            PipelineConfig {
                predict: PredictKind::LowBitMul,
                topk: TopkKind::Vanilla,
                ..PipelineConfig::star().with_keep(0.3)
            },
        ),
        (
            "threshold",
            PipelineConfig { topk: TopkKind::Threshold, ..PipelineConfig::star().with_keep(0.2) },
        ),
        (
            "oracle_vanilla",
            PipelineConfig {
                predict: PredictKind::None,
                topk: TopkKind::Vanilla,
                ..PipelineConfig::star().with_keep(0.25)
            },
        ),
        ("dense_oracle", PipelineConfig::dense_oracle()),
    ];
    for (label, cfg) in engines {
        let cfg = cfg.with_tile(8).with_threads(1);
        let single = SparseAttentionPipeline::new(cfg);
        for w in [1usize, 3, 8] {
            let sharded = ShardedPipeline::new(cfg, w);
            for (ci, chunks) in [vec![4usize, 5, 9, 18], vec![1; n]].iter().enumerate() {
                let (mut st_s, mut st_r) = (store_for(&cfg, d, 0), store_for(&cfg, d, 0));
                let mut at = 0usize;
                for &c in chunks {
                    let tag = format!("{label} shards={w} chunking={ci} at={at}+{c}");
                    step_both(
                        &sharded,
                        &single,
                        &mut st_s,
                        &mut st_r,
                        &sub(&q, at, at + c),
                        &sub(&k, at, at + c),
                        &sub(&v, at, at + c),
                        &tag,
                    );
                    at += c;
                }
            }
        }
    }
}

#[test]
fn eviction_and_rematerialization_mid_session_preserve_parity() {
    // Two sessions ping-pong through capacity-bounded twin stores that
    // cannot hold both (40 tokens / page 8 = 5 pages per session,
    // capacity 6 < 10): every switch evicts the other session, every
    // step after an eviction re-materializes pages from history. The
    // sharded path must replay the identical eviction schedule AND the
    // identical math.
    let (n, d) = (40usize, 8usize);
    let (qa, ka, va) = toks(n, d, 5);
    let (qb, kb, vb) = toks(n, d, 6);
    let cfg = PipelineConfig::star().with_keep(0.3).with_tile(8).with_threads(1);
    let single = SparseAttentionPipeline::new(cfg);
    let sharded = ShardedPipeline::new(cfg, 3);
    let (mut st_s, mut st_r) = (store_for(&cfg, d, 6), store_for(&cfg, d, 6));
    let chunk = 4usize;
    let mut remat_seen = 0usize;
    for start in (0..n).step_by(chunk) {
        let end = start + chunk;
        for (sid, (q, k, v)) in [(1u64, (&qa, &ka, &va)), (2, (&qb, &kb, &vb))] {
            let tag = format!("session {sid} at {start}..{end}");
            let (qc, kc, vc) = (sub(q, start, end), sub(k, start, end), sub(v, start, end));
            let rs = sharded.decode_step(&mut st_s, sid, &qc, &kc, &vc).expect("sharded step");
            let rr = single.decode_step(&mut st_r, sid, &qc, &kc, &vc).expect("single-core step");
            assert_eq!(rs.selection, rr.selection, "{tag}: selection drift");
            assert_eq!(rs.out.max_abs_diff(&rr.out), 0.0, "{tag}: output drift");
            assert_eq!(rs.stalls, rr.stalls, "{tag}: stall drift");
            // The cache side-effects replay identically too.
            assert_eq!(rs.evicted_sessions, rr.evicted_sessions, "{tag}: eviction drift");
            assert_eq!(
                rs.rematerialized_pages, rr.rematerialized_pages,
                "{tag}: re-materialization drift"
            );
            assert_eq!(rs.page_hits, rr.page_hits, "{tag}: page-hit drift");
            remat_seen += rs.rematerialized_pages;
        }
    }
    let stats = st_s.stats();
    assert!(stats.sessions_evicted > 0, "the pool was sized to force eviction");
    assert!(stats.pages_rematerialized > 0 && remat_seen > 0, "evicted sessions were rebuilt");
}

#[test]
fn warm_sharded_decode_hot_path_allocates_nothing() {
    assert!(allocmeter::installed(), "this binary installs the counting allocator");
    let (n, d) = (64usize, 16usize);
    let (q, k, v) = toks(n, d, 31);
    let cfg = PipelineConfig::star().with_keep(0.25).with_tile(8).with_threads(1);
    let sharded = ShardedPipeline::new(cfg, 3);
    let pool = WorkspacePool::new();
    let mut store = store_for(&cfg, d, 0);
    // The prefill chunk warms every worker's pooled workspace.
    let warm = sharded
        .decode_step_pooled(&mut store, 1, &sub(&q, 0, 32), &sub(&k, 0, 32), &sub(&v, 0, 32), &pool)
        .expect("warming prefill");
    assert!(warm.workspace_bytes > 0, "workers ran inside pooled workspaces");
    for pos in 32..n {
        let r = sharded
            .decode_step_pooled(
                &mut store,
                1,
                &sub(&q, pos, pos + 1),
                &sub(&k, pos, pos + 1),
                &sub(&v, pos, pos + 1),
                &pool,
            )
            .expect("warm decode step");
        assert_eq!(
            r.hot_path_allocs, 0,
            "warm sharded decode step at pos {pos} allocated on the heap"
        );
    }
}

#[test]
fn traffic_totals_match_single_core_except_candidate_scatter() {
    // Byte-for-byte traffic parity: with counting on, the sharded
    // decode's summed counters equal the single core's in every field
    // except `ring_payload_bytes` — the candidate scatter is the one
    // genuinely new data movement (shards' scored spans partition the
    // single core's [0, limit) span; the gather/formal charges come
    // from the shared core).
    star::obs::traffic::set_enabled(true);
    let (n, d) = (40usize, 16usize);
    let (q, k, v) = toks(n, d, 41);
    let cfg = PipelineConfig::star().with_keep(0.25).with_tile(8).with_threads(1);
    let single = SparseAttentionPipeline::new(cfg);
    let sharded = ShardedPipeline::new(cfg, 4);
    let (mut st_s, mut st_r) = (store_for(&cfg, d, 0), store_for(&cfg, d, 0));
    let (mut total_s, mut total_r) = (TrafficCounter::new(), TrafficCounter::new());
    for pos in 0..n {
        let (sq, sk, sv) =
            (sub(&q, pos, pos + 1), sub(&k, pos, pos + 1), sub(&v, pos, pos + 1));
        let rs = sharded.decode_step(&mut st_s, 1, &sq, &sk, &sv).expect("sharded step");
        let rr = single.decode_step(&mut st_r, 1, &sq, &sk, &sv).expect("single step");
        total_s.merge(&rs.traffic);
        total_r.merge(&rr.traffic);
    }
    star::obs::traffic::set_enabled(false);
    assert!(total_s.ring_payload_bytes > 0, "4-way decode scattered no candidates");
    assert_eq!(total_r.ring_payload_bytes, 0, "single core has no scatter");
    let mut s_adj = total_s;
    s_adj.ring_payload_bytes = 0;
    assert_eq!(s_adj, total_r, "traffic drift beyond the candidate scatter");
}

#[test]
fn kernel_path_leg_matches_feature_and_keeps_parity() {
    // Pin the dispatch so the default leg really tests Scalar and the
    // `--features simd` leg really tests Lanes, then re-check parity
    // under whichever spelling is active.
    assert_eq!(KernelPath::active() == KernelPath::Lanes, cfg!(feature = "simd"));
    let (n, d) = (32usize, 16usize);
    let (q, k, v) = toks(n, d, 53);
    let cfg = PipelineConfig::star().with_keep(0.3).with_tile(8).with_threads(1);
    let single = SparseAttentionPipeline::new(cfg);
    let sharded = ShardedPipeline::new(cfg, 5);
    let (mut st_s, mut st_r) = (store_for(&cfg, d, 0), store_for(&cfg, d, 0));
    for pos in 0..n {
        let tag = format!("{:?} pos={pos}", KernelPath::active());
        step_both(
            &sharded,
            &single,
            &mut st_s,
            &mut st_r,
            &sub(&q, pos, pos + 1),
            &sub(&k, pos, pos + 1),
            &sub(&v, pos, pos + 1),
            &tag,
        );
    }
}
