//! Property-based tests over the coordinator and algorithm invariants
//! (randomized via the in-tree mini-prop framework; the offline
//! environment has no proptest crate).

use star::arith::OpCounter;
use star::attention::{masked_attention_oracle, sufa_attention, AttnInputs, Selection, SufaParams};
use star::coordinator::{Batch, Batcher, BatcherConfig, Request};
use star::pipeline::{PipelineConfig, PipelineInputs, SparseAttentionPipeline};
use star::spatial::mrca::{mrca_schedule, total_hops, verify_schedule};
use star::sparsity::topk::{sads_topk, vanilla_topk, SadsParams};
use star::tensor::Mat;
use star::testing;
use star::util::Rng;

/// SU-FA equals the masked-softmax oracle for ANY true-score-descending
/// selection, on random shapes and sparsity patterns.
#[test]
fn prop_sufa_equals_masked_oracle() {
    testing::check(
        601,
        |rng: &mut Rng| {
            (rng.range(1, 12), rng.range(4, 96), rng.range(2, 24), rng.next_u64())
        },
        |&(t, s, d, seed)| {
            let mut rng = Rng::new(seed);
            let q = Mat::randn(t, d, 1.0, &mut rng);
            let k = Mat::randn(s, d, 1.0, &mut rng);
            let v = Mat::randn(s, d, 1.0, &mut rng);
            let inp = AttnInputs::new(&q, &k, &v);
            let keep = rng.range(1, s + 1);
            // Selection sorted by TRUE scores (descending).
            let exact = q.matmul(&k.transpose());
            let mut c = OpCounter::new();
            let rows: Vec<Vec<usize>> =
                (0..t).map(|i| vanilla_topk(exact.row(i), keep, &mut c)).collect();
            let sel = Selection { rows };
            let r = sufa_attention(&inp, &sel, &SufaParams::default(), &mut c);
            let want = masked_attention_oracle(&inp, &sel);
            let err = r.out.max_abs_diff(&want);
            star::prop_assert!(err < 1e-4, "t={t} s={s} d={d} keep={keep}: err {err}");
            Ok(())
        },
    );
}

/// SADS returns at most min(k, s) distinct in-range indices (fewer only
/// under tight-radius pruning), and never out-compares vanilla.
#[test]
fn prop_sads_selection_wellformed_and_cheaper() {
    testing::check(
        802,
        |rng: &mut Rng| {
            let s = rng.range(8, 512);
            (s, rng.range(1, s + 1), rng.range(1, 9), 2.0 + rng.f32() * 6.0, rng.next_u64())
        },
        |&(s, k, segments, radius, seed)| {
            let mut rng = Rng::new(seed);
            let row: Vec<f32> = (0..s).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            let p = SadsParams { segments, radius };
            let mut cs = OpCounter::new();
            let (idx, stats) = sads_topk(&row, k, &p, &mut cs);
            // A tight sphere radius may prune a segment below its quota —
            // by Eq. 5 those elements are negligible, so SADS returns
            // fewer than k. Never more, and never empty.
            star::prop_assert!(idx.len() <= k.min(s), "len {} > {}", idx.len(), k.min(s));
            star::prop_assert!(!idx.is_empty(), "selection must be non-empty");
            // With an effectively-unbounded radius the quota is exact.
            let mut c2 = OpCounter::new();
            let p_wide = SadsParams { segments, radius: 1e9 };
            let (idx_wide, _) = sads_topk(&row, k, &p_wide, &mut c2);
            star::prop_assert!(idx_wide.len() == k.min(s), "wide-radius len {}", idx_wide.len());
            let mut seen = vec![false; s];
            for &j in &idx {
                star::prop_assert!(j < s, "index {j} out of range");
                star::prop_assert!(!seen[j], "duplicate index {j}");
                seen[j] = true;
            }
            star::prop_assert!((0.0..=1.0).contains(&stats.rho), "rho {}", stats.rho);
            let mut cv = OpCounter::new();
            let _ = vanilla_topk(&row, k, &mut cv);
            star::prop_assert!(
                cs.cmp <= cv.cmp + s as u64,
                "sads {} !<= vanilla {}",
                cs.cmp,
                cv.cmp
            );
            Ok(())
        },
    );
}

/// The global maximum always survives SADS (it anchors its segment's
/// sphere), so the softmax-critical element is never lost.
#[test]
fn prop_sads_keeps_global_max() {
    testing::check(
        803,
        |rng: &mut Rng| {
            let s = rng.range(4, 256);
            (s, rng.range(1, s.min(32) + 1), rng.range(1, 7), rng.next_u64())
        },
        |&(s, k, segments, seed)| {
            let mut rng = Rng::new(seed);
            let row: Vec<f32> = (0..s).map(|_| rng.normal_f32(0.0, 3.0)).collect();
            let arg_max =
                (0..s).max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap()).unwrap();
            let p = SadsParams { segments, radius: 5.0 };
            let mut c = OpCounter::new();
            let (idx, _) = sads_topk(&row, k, &p, &mut c);
            star::prop_assert!(idx.contains(&arg_max), "global max not selected");
            Ok(())
        },
    );
}

/// Pipeline invariant: on random shapes/tile sizes, every selection row
/// holds distinct in-range key indices bounded by the configured keep,
/// and total op counts are monotone in the keep ratio.
#[test]
fn prop_pipeline_selection_wellformed_and_ops_monotone_in_keep() {
    testing::check(
        905,
        |rng: &mut Rng| {
            (
                rng.range(1, 24),   // t
                rng.range(8, 160),  // s
                rng.range(4, 32),   // d
                rng.range(1, 12),   // tile_t
                rng.next_u64(),
            )
        },
        |&(t, s, d, tile_t, seed)| {
            let mut rng = Rng::new(seed);
            let q = Mat::randn(t, d, 1.0, &mut rng);
            let k = Mat::randn(s, d, 1.0, &mut rng);
            let v = Mat::randn(s, d, 1.0, &mut rng);
            let inputs = PipelineInputs::qkv(&q, &k, &v);
            let lo_cfg = PipelineConfig::star().with_keep(0.1).with_tile(tile_t).with_threads(1);
            let hi_cfg = PipelineConfig::star().with_keep(0.4).with_tile(tile_t).with_threads(1);
            let lo = SparseAttentionPipeline::new(lo_cfg).run(&inputs);
            let hi = SparseAttentionPipeline::new(hi_cfg).run(&inputs);
            for r in [&lo, &hi] {
                star::prop_assert!(r.selection.rows.len() == t, "row count {}", r.selection.rows.len());
                for (i, row) in r.selection.rows.iter().enumerate() {
                    star::prop_assert!(row.len() <= r.keep, "row {i} keeps {} > {}", row.len(), r.keep);
                    let mut seen = vec![false; s];
                    for &j in row {
                        star::prop_assert!(j < s, "row {i}: index {j} out of range for S={s}");
                        star::prop_assert!(!seen[j], "row {i}: duplicate index {j}");
                        seen[j] = true;
                    }
                }
            }
            // More kept keys can only mean more work, for every op class
            // the stages emit.
            let (a, b) = (lo.total_ops(), hi.total_ops());
            star::prop_assert!(a.mul <= b.mul, "mul not monotone: {} > {}", a.mul, b.mul);
            star::prop_assert!(a.add <= b.add, "add not monotone: {} > {}", a.add, b.add);
            star::prop_assert!(a.exp <= b.exp, "exp not monotone: {} > {}", a.exp, b.exp);
            star::prop_assert!(
                a.equiv() <= b.equiv(),
                "equiv adds not monotone: {} > {}",
                a.equiv(),
                b.equiv()
            );
            Ok(())
        },
    );
}

/// MRCA completeness + neighbor-only + bounded storage for every N.
#[test]
fn prop_mrca_invariants() {
    for n in 1..=20 {
        let sched = mrca_schedule(n);
        assert_eq!(sched.len(), n);
        let chk = verify_schedule(n, &sched).unwrap_or_else(|e| panic!("N={n}: {e}"));
        assert!(chk.complete, "N={n}");
        assert!(chk.max_resident <= 3, "N={n}");
        assert!(chk.max_sends_per_cu <= 2, "N={n}");
        assert!(total_hops(&sched) <= 2 * n * n, "N={n}: hop budget");
    }
}

/// Batcher conservation: every pushed request is emitted exactly once,
/// in arrival order, and batches never exceed the target (except a
/// single oversize request).
#[test]
fn prop_batcher_conserves_requests() {
    testing::check(
        604,
        |rng: &mut Rng| (rng.range(8, 128), rng.range(1, 40), rng.next_u64()),
        |&(target, n, seed)| {
            let mut rng = Rng::new(seed);
            let cfg = BatcherConfig { target_t: target, max_wait_s: 0.0 };
            let mut b = Batcher::new("v", cfg);
            let mut pushed = Vec::new();
            for id in 0..n as u64 {
                let t = rng.range(1, target * 2);
                pushed.push(id);
                b.push(Request::new(id, "m", t, 64, 0.0));
            }
            let mut emitted = Vec::new();
            let mut guard = 0;
            while let Some(batch) = poll_or_flush(&mut b) {
                let rows = batch.rows();
                if batch.requests.len() > 1 {
                    star::prop_assert!(rows <= target, "batch over target: {rows}");
                }
                for r in &batch.requests {
                    emitted.push(r.id);
                }
                guard += 1;
                star::prop_assert!(guard < 1000, "batcher must terminate");
            }
            star::prop_assert!(emitted == pushed, "exactly-once order: {emitted:?} vs {pushed:?}");
            Ok(())
        },
    );
}

fn poll_or_flush(b: &mut Batcher) -> Option<Batch> {
    b.poll(1e9).or_else(|| b.flush(1e9))
}
