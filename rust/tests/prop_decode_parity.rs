//! Property: decode *is* prefill, bit for bit.
//!
//! N single-token `decode_step` calls must produce bit-identical outputs
//! and selections to one full causal prefill of length N — across chunk
//! boundaries, tile sizes (which also change the KV page size), thread
//! counts, pipeline configurations, and LRU eviction followed by
//! re-materialization. This is the contract that makes the paged
//! KV-cache a pure optimization: caching across time never changes the
//! math (ISSUE 3 acceptance criterion).

use star::attention::Selection;
use star::kvcache::{SessionConfig, SessionStore};
use star::pipeline::{PipelineConfig, SparseAttentionPipeline};
use star::sim::pipeline::{FormalKind, PredictKind, TopkKind};
use star::tensor::Mat;
use star::util::Rng;

fn toks(n: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
    let mut rng = Rng::new(seed);
    (
        Mat::randn(n, d, 1.0, &mut rng),
        Mat::randn(n, d, 1.0, &mut rng),
        Mat::randn(n, d, 1.0, &mut rng),
    )
}

fn sub(m: &Mat, lo: usize, hi: usize) -> Mat {
    Mat::from_fn(hi - lo, m.cols, |i, j| m.at(lo + i, j))
}

/// Feed the tokens through a fresh session in the given chunk sizes;
/// return the concatenated outputs and selections.
fn run_chunks(
    cfg: &PipelineConfig,
    capacity_pages: usize,
    chunks: &[usize],
    q: &Mat,
    k: &Mat,
    v: &Mat,
) -> (Mat, Selection) {
    let n = q.rows;
    assert_eq!(chunks.iter().sum::<usize>(), n, "chunking must cover all tokens");
    let pipe = SparseAttentionPipeline::new(*cfg);
    let mut store = SessionStore::new(SessionConfig::for_pipeline(cfg, q.cols, capacity_pages));
    let mut out = Mat::zeros(n, q.cols);
    let mut sel_rows = Vec::with_capacity(n);
    let mut at = 0usize;
    for &c in chunks {
        let r = pipe
            .decode_step(&mut store, 1, &sub(q, at, at + c), &sub(k, at, at + c), &sub(v, at, at + c))
            .expect("decode step");
        assert_eq!(r.positions, at..at + c, "positions track the session");
        for i in 0..c {
            out.row_mut(at + i).copy_from_slice(r.out.row(i));
        }
        sel_rows.extend(r.selection.rows);
        at += c;
    }
    (out, Selection { rows: sel_rows })
}

fn assert_bit_identical(
    (got_out, got_sel): &(Mat, Selection),
    (want_out, want_sel): &(Mat, Selection),
    what: &str,
) {
    assert_eq!(got_sel, want_sel, "{what}: selection drift");
    assert_eq!(got_out.max_abs_diff(want_out), 0.0, "{what}: output drift");
}

#[test]
fn single_token_decode_equals_full_prefill_across_tiles_and_threads() {
    let (n, d) = (40usize, 16usize);
    for seed in [1u64, 2] {
        let (q, k, v) = toks(n, d, seed);
        let base = PipelineConfig::star().with_keep(0.3);
        // Reference: one full prefill, default tile, single thread.
        let full = run_chunks(&base.with_tile(64).with_threads(1), 0, &[n], &q, &k, &v);
        // Per-token decode under varying tile sizes (⇒ varying KV page
        // sizes) and thread counts.
        for (tile, threads) in [(64usize, 1usize), (4, 1), (7, 4), (16, 2)] {
            let cfg = base.with_tile(tile).with_threads(threads);
            let stepped = run_chunks(&cfg, 0, &vec![1; n], &q, &k, &v);
            assert_bit_identical(
                &stepped,
                &full,
                &format!("seed={seed} tile={tile} threads={threads} per-token"),
            );
            let whole = run_chunks(&cfg, 0, &[n], &q, &k, &v);
            assert_bit_identical(
                &whole,
                &full,
                &format!("seed={seed} tile={tile} threads={threads} one-chunk"),
            );
        }
    }
}

#[test]
fn arbitrary_chunking_is_invariant() {
    let (n, d) = (48usize, 8usize);
    let (q, k, v) = toks(n, d, 3);
    let cfg = PipelineConfig::star().with_keep(0.25).with_tile(8).with_threads(2);
    let full = run_chunks(&cfg, 0, &[n], &q, &k, &v);
    let mut rng = Rng::new(99);
    for trial in 0..4 {
        let mut chunks = Vec::new();
        let mut left = n;
        while left > 0 {
            let c = rng.range(1, 9.min(left + 1));
            chunks.push(c);
            left -= c;
        }
        // Robustness: empty decode chunks are legal no-ops.
        if trial == 0 {
            chunks.insert(1, 0);
        }
        let got = run_chunks(&cfg, 0, &chunks, &q, &k, &v);
        assert_bit_identical(&got, &full, &format!("trial={trial} chunks={chunks:?}"));
    }
}

#[test]
fn parity_holds_across_pipeline_configurations() {
    let (n, d) = (36usize, 16usize);
    let (q, k, v) = toks(n, d, 4);
    let configs: Vec<(&str, PipelineConfig)> = vec![
        ("star", PipelineConfig::star().with_keep(0.3)),
        ("ds_baseline", PipelineConfig::ds_baseline().with_keep(0.3)),
        ("dense_oracle", PipelineConfig::dense_oracle()),
        (
            "slzs_ascend",
            PipelineConfig {
                predict: PredictKind::Slzs,
                topk: TopkKind::Sads,
                formal: FormalKind::SufaAscend,
                ..PipelineConfig::star().with_keep(0.4)
            },
        ),
        (
            "oracle_vanilla",
            PipelineConfig {
                predict: PredictKind::None,
                topk: TopkKind::Vanilla,
                ..PipelineConfig::star().with_keep(0.2)
            },
        ),
    ];
    for (label, cfg) in configs {
        let cfg = cfg.with_tile(8).with_threads(1);
        let full = run_chunks(&cfg, 0, &[n], &q, &k, &v);
        let stepped = run_chunks(&cfg, 0, &vec![1; n], &q, &k, &v);
        assert_bit_identical(&stepped, &full, label);
        // Causality: row at position p selects only keys ≤ p.
        for (p, row) in full.1.rows.iter().enumerate() {
            assert!(row.iter().all(|&j| j <= p), "{label}: row {p} selects a future key");
        }
    }
}

#[test]
fn eviction_and_rematerialization_preserve_parity() {
    // Two sessions ping-pong in a pool that cannot hold both: every
    // switch evicts the other session and every step after an eviction
    // re-materializes pages from history. Outputs must match the
    // unbounded-pool run bit for bit, for both sessions.
    let (n, d) = (40usize, 8usize);
    let (qa, ka, va) = toks(n, d, 5);
    let (qb, kb, vb) = toks(n, d, 6);
    let cfg = PipelineConfig::star().with_keep(0.3).with_tile(8).with_threads(1);
    let full_a = run_chunks(&cfg, 0, &[n], &qa, &ka, &va);
    let full_b = run_chunks(&cfg, 0, &[n], &qb, &kb, &vb);

    // 40 tokens / page_size 8 = 5 pages per session; capacity 6 < 10.
    let pipe = SparseAttentionPipeline::new(cfg);
    let mut store = SessionStore::new(SessionConfig::for_pipeline(&cfg, d, 6));
    let mut out_a = Mat::zeros(n, d);
    let mut out_b = Mat::zeros(n, d);
    let mut sel_a = Vec::new();
    let mut sel_b = Vec::new();
    let chunk = 4usize;
    for start in (0..n).step_by(chunk) {
        let end = start + chunk;
        let ra = pipe
            .decode_step(&mut store, 1, &sub(&qa, start, end), &sub(&ka, start, end), &sub(&va, start, end))
            .expect("session A step");
        for i in 0..chunk {
            out_a.row_mut(start + i).copy_from_slice(ra.out.row(i));
        }
        sel_a.extend(ra.selection.rows);
        let rb = pipe
            .decode_step(&mut store, 2, &sub(&qb, start, end), &sub(&kb, start, end), &sub(&vb, start, end))
            .expect("session B step");
        for i in 0..chunk {
            out_b.row_mut(start + i).copy_from_slice(rb.out.row(i));
        }
        sel_b.extend(rb.selection.rows);
    }
    let stats = store.stats();
    assert!(stats.sessions_evicted > 0, "the pool was sized to force eviction");
    assert!(stats.pages_rematerialized > 0, "evicted sessions were rebuilt");
    assert_bit_identical(&(out_a, Selection { rows: sel_a }), &full_a, "evicted session A");
    assert_bit_identical(&(out_b, Selection { rows: sel_b }), &full_b, "evicted session B");
}

#[test]
fn decode_matches_masked_oracle_numerically() {
    // Sanity beyond self-consistency: the decoded outputs are the exact
    // softmax over each row's (causal, absolute-indexed) selection.
    use star::attention::{masked_attention_oracle, AttnInputs};
    let (n, d) = (32usize, 16usize);
    let (q, k, v) = toks(n, d, 7);
    let cfg = PipelineConfig::star().with_keep(0.4).with_tile(8).with_threads(1);
    let (out, sel) = run_chunks(&cfg, 0, &vec![2; n / 2], &q, &k, &v);
    let inp = AttnInputs::new(&q, &k, &v);
    let oracle = masked_attention_oracle(&inp, &sel);
    let err = out.max_abs_diff(&oracle);
    assert!(err < 1e-4, "masked-oracle parity err {err}");
}
