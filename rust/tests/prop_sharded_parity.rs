//! Property: sequence-sharded execution never changes the math.
//!
//! [`star::pipeline::ShardedPipeline`] must produce **bit-identical**
//! outputs, selections and stall counts to the single-core
//! [`star::pipeline::SparseAttentionPipeline`] on the same inputs — for
//! every worker count (including counts that split SADS segments
//! unevenly), every tile size, and sequence lengths that do not divide
//! evenly into segments or shards. The three pillars under test:
//! global-scale quantization ([`star::sparsity::PreparedPredict`]),
//! segment-aligned sharding of the SADS top-k, and the order-preserving
//! KV gather ahead of the formal stage.

use star::config::ModelConfig;
use star::pipeline::{PipelineConfig, PipelineInputs, ShardedPipeline, SparseAttentionPipeline};
use star::sim::pipeline::{FormalKind, PredictKind, TopkKind};
use star::tensor::Mat;
use star::util::Rng;
use star::workload::AttnWorkload;

fn workload(t: usize, s: usize, seed: u64) -> AttnWorkload {
    let model = ModelConfig::preset("tiny").unwrap();
    let mut rng = Rng::new(seed);
    AttnWorkload::generate(&model, s, t, &mut rng)
}

/// Assert the full bit-identity contract between one sharded run and
/// the single-core reference.
fn assert_parity(
    tag: &str,
    single: &star::pipeline::PipelineReport,
    sharded: &star::pipeline::ShardedReport,
) {
    assert_eq!(sharded.selection, single.selection, "{tag}: selection drift");
    assert_eq!(
        sharded.out.max_abs_diff(&single.out),
        0.0,
        "{tag}: output drift (max abs diff {})",
        sharded.out.max_abs_diff(&single.out)
    );
    assert_eq!(sharded.stalls, single.stalls, "{tag}: SU-FA stall drift");
    assert_eq!(sharded.keep, single.keep, "{tag}: keep drift");
}

#[test]
fn star_stack_bit_identical_across_shard_counts() {
    // The full STAR stack (cross-phase DLZS + SADS + on-demand KV +
    // descending SU-FA) from workload activations.
    for (t, s, seed) in [(24usize, 96usize, 11u64), (48, 130, 12)] {
        let wl = workload(t, s, seed);
        let inputs = PipelineInputs::from_workload(&wl);
        for tile in [7usize, 64] {
            let cfg = PipelineConfig::star().with_keep(0.25).with_tile(tile).with_threads(1);
            let single = SparseAttentionPipeline::new(cfg).run(&inputs);
            for shards in [1usize, 2, 4] {
                let sharded = ShardedPipeline::new(cfg, shards).run(&inputs);
                let tag = format!("t={t} s={s} tile={tile} shards={shards}");
                assert_parity(&tag, &single, &sharded);
                // SADS sharding is comparison-exact, and prediction
                // work is the same dot products either way.
                assert_eq!(sharded.ops.predict, single.ops.predict, "{tag}: predict ops");
                assert_eq!(sharded.ops.topk, single.ops.topk, "{tag}: topk ops");
            }
        }
    }
}

#[test]
fn non_divisible_lengths_and_uneven_segment_splits() {
    // S = 257 → SADS segment length 65 with a short tail segment; 3
    // workers own {1, 1, 2} segments — the most lopsided split. T = 17
    // does not divide into blocks evenly either.
    let wl = workload(17, 257, 21);
    let inputs = PipelineInputs::from_workload(&wl);
    let cfg = PipelineConfig::star().with_keep(0.2).with_tile(5).with_threads(1);
    let single = SparseAttentionPipeline::new(cfg).run(&inputs);
    for shards in [1usize, 2, 3, 4, 16] {
        let sharded = ShardedPipeline::new(cfg, shards).run(&inputs);
        let tag = format!("shards={shards}");
        assert_parity(&tag, &single, &sharded);
        assert!(sharded.shards <= 4, "{tag}: clamped to the SADS segment count");
    }
}

#[test]
fn exact_and_oracle_engines_match_across_shards() {
    // Vanilla top-k (exact distributed merge) under both an oracle
    // score source (predict = None → exact Q·Kᵀ) and the low-bit
    // multiply predictor, on plain Q/K/V inputs.
    let mut rng = Rng::new(31);
    let (t, s, d) = (19usize, 101usize, 16usize);
    let q = Mat::randn(t, d, 1.0, &mut rng);
    let k = Mat::randn(s, d, 1.0, &mut rng);
    let v = Mat::randn(s, d, 1.0, &mut rng);
    let inputs = PipelineInputs::qkv(&q, &k, &v);
    for predict in [PredictKind::None, PredictKind::LowBitMul] {
        let cfg = PipelineConfig {
            predict,
            topk: TopkKind::Vanilla,
            on_demand_kv: false,
            ..PipelineConfig::star().with_keep(0.3).with_threads(1)
        };
        let single = SparseAttentionPipeline::new(cfg).run(&inputs);
        for shards in [1usize, 2, 4, 7] {
            let sharded = ShardedPipeline::new(cfg, shards).run(&inputs);
            assert_parity(&format!("{predict:?} shards={shards}"), &single, &sharded);
        }
    }
}

#[test]
fn slzs_flash2_combination_matches_across_shards() {
    // A non-default stage mix: symmetric LZ prediction into SADS into
    // the FA-2-style formal kernel.
    let wl = workload(21, 144, 41);
    let inputs = PipelineInputs::from_workload(&wl);
    let cfg = PipelineConfig {
        predict: PredictKind::Slzs,
        formal: FormalKind::Flash2,
        ..PipelineConfig::star().with_keep(0.25).with_threads(1)
    };
    let single = SparseAttentionPipeline::new(cfg).run(&inputs);
    for shards in [1usize, 3, 4] {
        let sharded = ShardedPipeline::new(cfg, shards).run(&inputs);
        assert_parity(&format!("slzs/fa2 shards={shards}"), &single, &sharded);
    }
}

#[test]
fn dense_oracle_matches_across_shards() {
    // keep = 1.0 with the dense formal kernel: the sharded gather holds
    // every key, and the remap is the identity.
    let wl = workload(13, 64, 51);
    let inputs = PipelineInputs::qkv(&wl.q, &wl.k, &wl.v);
    let cfg = PipelineConfig::dense_oracle().with_threads(1);
    let single = SparseAttentionPipeline::new(cfg).run(&inputs);
    for shards in [1usize, 2, 4] {
        let sharded = ShardedPipeline::new(cfg, shards).run(&inputs);
        assert_parity(&format!("dense shards={shards}"), &single, &sharded);
        assert_eq!(sharded.density(64), 1.0);
    }
}

#[test]
fn auto_shard_count_is_still_bit_identical() {
    // shards = 0 → one worker per core: whatever the machine, the
    // output cannot change (the property CI machines actually exercise
    // with varying core counts).
    let wl = workload(16, 128, 61);
    let inputs = PipelineInputs::from_workload(&wl);
    let cfg = PipelineConfig::star().with_keep(0.25).with_threads(1);
    let single = SparseAttentionPipeline::new(cfg).run(&inputs);
    let sharded = ShardedPipeline::new(cfg, 0).run(&inputs);
    assert_parity("auto", &single, &sharded);
    assert!(sharded.shards >= 1);
}
