//! Property: the lane-spelled kernels never change the math.
//!
//! Every hot buffer-writing kernel is compiled in **both** spellings
//! ([`star::arith::KernelPath::Scalar`] and `::Lanes`) in every build;
//! the `simd` cargo feature only flips which spelling the dispatchers
//! pick ([`star::arith::KernelPath::active`]). Two layers of contract:
//!
//! 1. **Kernel bit-identity.** For each kernel, the two spellings are
//!    compared in one binary on adversarial inputs: remainder widths
//!    around the 8-wide lane count, ±∞ / NaN-adjacent scores, planted
//!    ties across chunk boundaries. Outputs, op tallies, stall counts
//!    and top-k selections must match bit for bit (`Strict` reduction,
//!    the default).
//! 2. **Pipeline closure.** All three execution paths (batch prefill,
//!    autoregressive decode, sequence-sharded) run through ONE
//!    [`star::pipeline::WorkspacePool`] under whichever spelling the
//!    build selected, and must agree with fresh-pool references and
//!    with each other. CI runs this binary with and without
//!    `--features simd`; together with layer 1 that closes the loop —
//!    the feature flag cannot move a single bit.
//!
//! The work-stealing tile scheduler rides the same contract: outputs,
//! selections and stalls are asserted identical at every thread count,
//! and the warm hot path still meters zero allocations (this binary
//! installs the counting allocator).

#[global_allocator]
static ALLOC: star::util::allocmeter::CountingAllocator =
    star::util::allocmeter::CountingAllocator;

use star::arith::{quantize_row_into_with, IntBits, KernelPath, OpCounter};
use star::attention::{sufa_attention_rows_into_with, AttnInputs, SufaParams, SufaScratch};
use star::kvcache::{SessionConfig, SessionStore};
use star::pipeline::{
    PipelineConfig, PipelineInputs, ShardedPipeline, SparseAttentionPipeline, WorkspacePool,
};
use star::sparsity::{vanilla_topk_into_with, PredictScheme, Predictor, TopkScratch};
use star::tensor::Mat;
use star::util::Rng;

fn bits_eq(a: &Mat, b: &Mat) -> bool {
    a.rows == b.rows
        && a.cols == b.cols
        && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn active_path_follows_the_cargo_feature() {
    // The feature unifies across every target of the package, so the
    // test binary and the library always agree on the dispatch choice.
    assert_eq!(KernelPath::active() == KernelPath::Lanes, cfg!(feature = "simd"));
}

#[test]
fn quantize_spellings_agree_on_remainders_and_nonfinite_rows() {
    let mut rng = Rng::new(71);
    let widths = (1usize..=17).chain([64, 65]);
    for len in widths {
        for bits in [IntBits::Int4, IntBits::Int8, IntBits::Int16] {
            let mut row: Vec<f32> = (0..len).map(|_| rng.range_f32(-6.0, 6.0)).collect();
            // Adversarial values on and around lane boundaries: a huge
            // magnitude (dominates amax), a negative zero (abs must
            // normalize it), a subnormal, and one NaN (both amax folds
            // must ignore it the way f32::max does).
            row[0] = -0.0;
            if len > 7 {
                row[7] = 3.0e38;
                row[8] = f32::NAN;
            }
            if len > 9 {
                row[9] = f32::MIN_POSITIVE / 2.0;
            }
            let (mut qs, mut ql) = (vec![7i32; 3], Vec::new());
            let ss = quantize_row_into_with(&row, bits, &mut qs, KernelPath::Scalar);
            let sl = quantize_row_into_with(&row, bits, &mut ql, KernelPath::Lanes);
            assert_eq!(ss.to_bits(), sl.to_bits(), "scale drift at len={len} {bits:?}");
            assert_eq!(qs, ql, "code drift at len={len} {bits:?}");
        }
    }
}

#[test]
fn matmul_spellings_agree_with_zeros_and_infinities() {
    let mut rng = Rng::new(72);
    for (m, k, n) in [(3usize, 10usize, 17usize), (5, 130, 9), (4, 8, 40)] {
        let mut a = Mat::from_fn(m, k, |_, _| rng.range_f32(-2.0, 2.0));
        let mut b = Mat::from_fn(k, n, |_, _| rng.range_f32(-2.0, 2.0));
        // Plant the skip-zero fast path next to infinities: a zero LHS
        // entry must skip an ∞ RHS row identically in both spellings,
        // and a surviving ∞ must poison the same accumulators to the
        // same ±∞/NaN bit patterns.
        a.data[0] = 0.0;
        a.data[k - 1] = f32::INFINITY;
        b.data[0] = f32::NEG_INFINITY;
        b.data[n - 1] = f32::INFINITY;
        let (mut os, mut ol) = (Mat::zeros(1, 1), Mat::zeros(7, 3));
        a.matmul_cols_into_with(&b, 0, n, &mut os, KernelPath::Scalar);
        a.matmul_cols_into_with(&b, 0, n, &mut ol, KernelPath::Lanes);
        assert!(bits_eq(&os, &ol), "matmul drift at {m}x{k}x{n}");
    }
}

#[test]
fn predictor_spellings_agree_on_extreme_magnitudes() {
    let mut rng = Rng::new(73);
    for scheme in [PredictScheme::Dlzs, PredictScheme::Slzs, PredictScheme::LowBitMul] {
        for d in [9usize, 13, 16] {
            let (t, s) = (6usize, 21usize);
            let mut q = Mat::from_fn(t, d, |_, _| rng.range_f32(-1.0, 1.0));
            let k = Mat::from_fn(s, d, |_, _| rng.range_f32(-1.0, 1.0));
            // One outlier row squashes everything else to the bottom
            // quantization bins — the integer dots stay exact either way.
            for x in q.row_mut(1) {
                *x *= 1.0e4;
            }
            let mut c = OpCounter::default();
            let prep = Predictor::new(scheme, 7).prepare(&q, &k, &mut c);
            let (mut cs, mut cl) = (OpCounter::default(), OpCounter::default());
            let (mut os, mut ol) = (Mat::zeros(1, 1), Mat::zeros(2, 2));
            prep.score_block_into_with(0, t, 0, s, &mut cs, &mut os, KernelPath::Scalar);
            prep.score_block_into_with(0, t, 0, s, &mut cl, &mut ol, KernelPath::Lanes);
            assert!(bits_eq(&os, &ol), "score drift {scheme:?} d={d}");
            assert_eq!(cs, cl, "op-tally drift {scheme:?} d={d}");
        }
    }
}

#[test]
fn topk_spellings_agree_on_ties_and_nonfinite_scores() {
    let mut rng = Rng::new(74);
    for len in [7usize, 8, 9, 16, 130] {
        let mut row: Vec<f32> = (0..len).map(|_| rng.range_f32(-8.0, 8.0)).collect();
        // A tie straddling a lane-chunk boundary (first index must win),
        // ±∞ and one NaN (never selectable, identically in both
        // spellings), and a ±0.0 pair (f32 equality treats them equal).
        row[2] = 5.5;
        if len > 9 {
            row[9] = 5.5;
            row[6] = f32::NEG_INFINITY;
            row[8] = f32::NAN;
            row[3] = 0.0;
            row[5] = -0.0;
        }
        if len > 64 {
            row[64] = f32::INFINITY;
        }
        for k in [1usize, 3, 8, len, len + 5] {
            let mut scratch = TopkScratch::default();
            let (mut cs, mut cl) = (OpCounter::default(), OpCounter::default());
            let (mut ss, mut sl) = (vec![99usize], Vec::new());
            vanilla_topk_into_with(&row, k, &mut cs, &mut scratch, &mut ss, KernelPath::Scalar);
            vanilla_topk_into_with(&row, k, &mut cl, &mut scratch, &mut sl, KernelPath::Lanes);
            assert_eq!(ss, sl, "selection drift at len={len} k={k}");
            assert_eq!(cs, cl, "comparison-count drift at len={len} k={k}");
        }
    }
}

#[test]
fn sufa_spellings_agree_under_overflowing_softmax() {
    // Scores large enough that exp() saturates/underflows, plus an ∞ in
    // one query row: every arithmetic step is elementwise-identical
    // across spellings under Strict reduction, so even the poisoned
    // rows must match bit for bit — as must the stall count.
    let mut rng = Rng::new(75);
    let (t, s, d) = (6usize, 40usize, 10usize);
    let mut q = Mat::from_fn(t, d, |_, _| rng.range_f32(-30.0, 30.0));
    let k = Mat::from_fn(s, d, |_, _| rng.range_f32(-30.0, 30.0));
    let v = Mat::from_fn(s, d, |_, _| rng.range_f32(-1.0, 1.0));
    q.row_mut(2)[0] = f32::INFINITY;
    let inp = AttnInputs::new(&q, &k, &v);
    let rows: Vec<Vec<usize>> = (0..t)
        .map(|i| {
            let mut sel = Rng::new(100 + i as u64).sample_indices(s, 13);
            if i % 2 == 0 {
                sel.sort_unstable();
            }
            sel
        })
        .collect();
    let p = SufaParams::default();
    let mut scratch = SufaScratch::default();
    let (mut cs, mut cl) = (OpCounter::default(), OpCounter::default());
    let (mut os, mut ol) = (Mat::zeros(1, 1), Mat::zeros(3, 3));
    let st_s = sufa_attention_rows_into_with(
        &inp,
        &rows,
        &p,
        &mut cs,
        &mut scratch,
        &mut os,
        KernelPath::Scalar,
    );
    let st_l = sufa_attention_rows_into_with(
        &inp,
        &rows,
        &p,
        &mut cl,
        &mut scratch,
        &mut ol,
        KernelPath::Lanes,
    );
    assert!(bits_eq(&os, &ol), "SU-FA output drift");
    assert_eq!(st_s, st_l, "SU-FA stall drift");
    assert_eq!(cs, cl, "SU-FA op-tally drift");
}

fn sub(m: &Mat, lo: usize, hi: usize) -> Mat {
    Mat::from_fn(hi - lo, m.cols, |i, j| m.at(lo + i, j))
}

#[test]
fn three_execution_paths_through_one_pool_agree() {
    // Whichever spelling this build dispatches to, the three execution
    // paths must produce mutually consistent, pool-independent results.
    let pool = WorkspacePool::new();
    let (t, s, d) = (26usize, 120usize, 16usize);
    let mut rng = Rng::new(91);
    let q = Mat::randn(t, d, 1.0, &mut rng);
    let k = Mat::randn(s, d, 1.0, &mut rng);
    let v = Mat::randn(s, d, 1.0, &mut rng);
    let inputs = PipelineInputs::qkv(&q, &k, &v);
    let cfg = PipelineConfig::star().with_keep(0.25).with_tile(7).with_threads(1);

    let fresh = SparseAttentionPipeline::new(cfg).run(&inputs);
    let pooled = SparseAttentionPipeline::new(cfg).run_pooled(&inputs, &pool);
    assert_eq!(pooled.selection, fresh.selection, "prefill selection drift");
    assert!(bits_eq(&pooled.out, &fresh.out), "prefill output drift");
    assert_eq!(pooled.stalls, fresh.stalls, "prefill stall drift");

    for shards in [2usize, 3] {
        let sharded = ShardedPipeline::new(cfg, shards).run_pooled(&inputs, &pool);
        assert_eq!(sharded.selection, fresh.selection, "sharded selection drift");
        assert!(bits_eq(&sharded.out, &fresh.out), "sharded output drift");
        assert_eq!(sharded.stalls, fresh.stalls, "sharded stall drift");
    }

    // Decode through the same (dirty) pool vs a fresh pool.
    let run_session = |pool: &WorkspacePool| {
        let pipe = SparseAttentionPipeline::new(cfg);
        let mut store = SessionStore::new(SessionConfig::for_pipeline(&cfg, d, 0));
        let mut outs = Vec::new();
        let mut at = 0usize;
        for &c in &[9usize, 1, 1, 8, 7] {
            let r = pipe
                .decode_step_pooled(
                    &mut store,
                    1,
                    &sub(&q, at, at + c),
                    &sub(&k, at, at + c),
                    &sub(&v, at, at + c),
                    pool,
                )
                .expect("decode step");
            outs.push((r.out, r.selection, r.stalls));
            at += c;
        }
        outs
    };
    let fresh_steps = run_session(&WorkspacePool::new());
    let pooled_steps = run_session(&pool);
    for (i, (f, p)) in fresh_steps.iter().zip(&pooled_steps).enumerate() {
        assert!(bits_eq(&p.0, &f.0), "decode step {i} output drift");
        assert_eq!(p.1, f.1, "decode step {i} selection drift");
        assert_eq!(p.2, f.2, "decode step {i} stall drift");
    }
}

#[test]
fn work_stealing_is_deterministic_and_allocation_free_at_every_thread_count() {
    // 16 tiles of skewed cost (keep grows with S so later tiles gather
    // more keys): whatever interleaving the chunked atomic cursor
    // produces, each tile runs exactly once as a pure function of its
    // index — outputs, selections, stalls and op tallies cannot move.
    let (t, s, d) = (64usize, 192usize, 16usize);
    let mut rng = Rng::new(92);
    let q = Mat::randn(t, d, 1.0, &mut rng);
    let k = Mat::randn(s, d, 1.0, &mut rng);
    let v = Mat::randn(s, d, 1.0, &mut rng);
    let inputs = PipelineInputs::qkv(&q, &k, &v);
    let base = PipelineConfig::star().with_keep(0.3).with_tile(4);
    let reference = SparseAttentionPipeline::new(base.with_threads(1)).run(&inputs);
    assert!(reference.tiles >= 16, "want enough tiles to exercise stealing");

    for threads in [1usize, 2, 3, 5, 8] {
        let pool = WorkspacePool::new();
        let pipe = SparseAttentionPipeline::new(base.with_threads(threads));
        let _warm = pipe.run_pooled(&inputs, &pool);
        let r = pipe.run_pooled(&inputs, &pool);
        let tag = format!("threads={threads}");
        assert_eq!(r.selection, reference.selection, "{tag}: selection drift");
        assert!(bits_eq(&r.out, &reference.out), "{tag}: output drift");
        assert_eq!(r.stalls, reference.stalls, "{tag}: stall drift");
        assert_eq!(r.ops.formal, reference.ops.formal, "{tag}: formal ops drift");
        assert_eq!(r.hot_path_allocs, 0, "{tag}: warm hot path allocated under work-stealing");

        let sharded = ShardedPipeline::new(base.with_threads(threads), 2).run(&inputs);
        assert_eq!(sharded.selection, reference.selection, "{tag}: sharded selection drift");
        assert!(bits_eq(&sharded.out, &reference.out), "{tag}: sharded output drift");
    }
}
