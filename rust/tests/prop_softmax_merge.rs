//! Unit + property tests for the cross-shard online-softmax combine
//! ([`star::attention::SoftmaxPartial`]) in isolation — the
//! tolerance-mode distributed formal kernel that `star bench decode`
//! measures (the bit-exact serving path gathers instead; DESIGN.md §12).
//!
//! The contracts:
//! * a **single whole-row partition** finalizes bit-identically to the
//!   SU-FA accumulator under [`star::attention::UpdateOrder::Ascend`]
//!   given the same visit order, on both kernel paths and both dot
//!   reductions;
//! * the fixed pairwise merge tree is **deterministic**: independent of
//!   when each shard's partial was computed or arrived;
//! * degenerate shards behave: empty selections are the combine
//!   identity (bitwise), all-empty rows finalize to zeros, single-key
//!   partitions are exact;
//! * **randomly partitioned rows** agree with the unsharded reduction
//!   to f32 rescale precision.

use star::arith::{KernelPath, OpCounter, ReductionOrder};
use star::attention::{
    merge_partials_tree, softmax_partial_into_with, sufa_attention_rows_into_with, AttnInputs,
    SoftmaxPartial, SufaParams, SufaScratch, UpdateOrder,
};
use star::tensor::Mat;
use star::util::Rng;

const PATHS: [KernelPath; 2] = [KernelPath::Scalar, KernelPath::Lanes];
const REDS: [ReductionOrder; 2] = [ReductionOrder::Strict, ReductionOrder::Lanes];

fn mats(t: usize, s: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
    let mut rng = Rng::new(seed);
    (
        Mat::randn(t, d, 1.0, &mut rng),
        Mat::randn(s, d, 1.0, &mut rng),
        Mat::randn(s, d, 1.0, &mut rng),
    )
}

/// Random per-row key subsets in random visit order (the top-k stage
/// emits score order; any fixed order is a valid contract input).
fn random_rows(t: usize, s: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    (0..t)
        .map(|_| {
            let n = rng.range(1, s + 1);
            let mut keys = rng.sample_indices(s, n);
            rng.shuffle(&mut keys);
            keys
        })
        .collect()
}

/// Accumulate one partial over `keys` and finalize it into a fresh row.
fn run_partition(
    q: &[f32],
    k: &Mat,
    v: &Mat,
    keys: &[usize],
    scale: f32,
    bc: usize,
    red: ReductionOrder,
    path: KernelPath,
) -> (SoftmaxPartial, Vec<f32>) {
    let mut c = OpCounter::new();
    let mut part = SoftmaxPartial::empty(q.len());
    softmax_partial_into_with(q, k, v, keys, scale, bc, red, &mut c, &mut part, path);
    let mut out = vec![0.0f32; q.len()];
    part.finalize_into_with(&mut c, &mut out, path);
    (part, out)
}

#[test]
fn single_partition_finalizes_bit_identically_to_ascend_sufa() {
    let (t, s, d) = (7usize, 64usize, 16usize);
    let (q, k, v) = mats(t, s, d, 1);
    let inp = AttnInputs::new(&q, &k, &v);
    let mut rng = Rng::new(2);
    let rows = random_rows(t, s, &mut rng);
    for path in PATHS {
        for red in REDS {
            for bc in [5usize, 16] {
                let p = SufaParams { bc, order: UpdateOrder::Ascend, reduction: red };
                let mut c = OpCounter::new();
                let mut scratch = SufaScratch::default();
                let mut want = Mat::zeros(t, d);
                sufa_attention_rows_into_with(
                    &inp,
                    &rows,
                    &p,
                    &mut c,
                    &mut scratch,
                    &mut want,
                    path,
                );
                for (i, keys) in rows.iter().enumerate() {
                    // Ascend consumes its list back-to-front; a single
                    // whole-row partition fed the reversed list replays
                    // the identical float sequence.
                    let rev: Vec<usize> = keys.iter().rev().copied().collect();
                    let (_, got) =
                        run_partition(q.row(i), &k, &v, &rev, inp.scale, bc, red, path);
                    assert_eq!(
                        got.as_slice(),
                        want.row(i),
                        "path={path:?} red={red:?} bc={bc} row={i}: single partition \
                         drifted from Ascend SU-FA"
                    );
                }
            }
        }
    }
}

#[test]
fn merge_is_deterministic_across_computation_and_arrival_order() {
    let (s, d) = (80usize, 24usize);
    let (q, k, v) = mats(1, s, d, 3);
    let scale = 1.0 / (d as f32).sqrt();
    let mut rng = Rng::new(4);
    let mut keys = rng.sample_indices(s, 61);
    rng.shuffle(&mut keys);
    for w in [2usize, 3, 5, 8] {
        let chunk = |j: usize| &keys[j * keys.len() / w..(j + 1) * keys.len() / w];
        let build = |order: &[usize]| {
            // Compute the shards' partials in an arbitrary order but
            // slot them by partition index — exactly what the home
            // worker does with out-of-order arrivals.
            let mut parts: Vec<SoftmaxPartial> =
                (0..w).map(|_| SoftmaxPartial::empty(d)).collect();
            let mut c = OpCounter::new();
            for &j in order {
                softmax_partial_into_with(
                    q.row(0),
                    &k,
                    &v,
                    chunk(j),
                    scale,
                    7,
                    ReductionOrder::Strict,
                    &mut c,
                    &mut parts[j],
                    KernelPath::Scalar,
                );
            }
            let merged = merge_partials_tree(&mut parts, &mut c);
            let mut out = vec![0.0f32; d];
            merged.finalize_into(&mut c, &mut out);
            (merged.m().to_bits(), merged.l().to_bits(), out)
        };
        let in_order: Vec<usize> = (0..w).collect();
        let a = build(&in_order);
        let mut shuffled = in_order.clone();
        rng.shuffle(&mut shuffled);
        let b = build(&shuffled);
        assert_eq!(a.0, b.0, "w={w}: max bits drift across arrival order");
        assert_eq!(a.1, b.1, "w={w}: denominator bits drift across arrival order");
        assert_eq!(a.2, b.2, "w={w}: output bits drift across arrival order");
    }
}

#[test]
fn degenerate_partitions_behave() {
    let (s, d) = (40usize, 8usize);
    let (q, k, v) = mats(1, s, d, 5);
    let scale = 1.0 / (d as f32).sqrt();
    let mut c = OpCounter::new();

    // Empty ⊕ empty stays empty; an all-empty row finalizes to zeros
    // (the l == 0 guard, not a 0/0 NaN).
    let mut a = SoftmaxPartial::empty(d);
    a.combine(&SoftmaxPartial::empty(d), &mut c);
    assert_eq!(a.m(), f32::NEG_INFINITY);
    assert_eq!(a.l(), 0.0);
    let mut out = vec![1.0f32; d];
    a.finalize_into(&mut c, &mut out);
    assert!(out.iter().all(|&x| x == 0.0), "empty row must finalize to zeros");

    // Empty shards are the combine identity, bitwise, from either side.
    let keys: Vec<usize> = (0..17).collect();
    let (real, real_out) = run_partition(
        q.row(0),
        &k,
        &v,
        &keys,
        scale,
        7,
        ReductionOrder::Strict,
        KernelPath::Scalar,
    );
    for (label, order) in [("empty-right", [1usize, 0]), ("empty-left", [0, 1])] {
        let mut acc = SoftmaxPartial::empty(d);
        for &which in &order {
            let other = if which == 0 {
                let (p, _) = run_partition(
                    q.row(0),
                    &k,
                    &v,
                    &keys,
                    scale,
                    7,
                    ReductionOrder::Strict,
                    KernelPath::Scalar,
                );
                p
            } else {
                SoftmaxPartial::empty(d)
            };
            acc.combine(&other, &mut c);
        }
        assert_eq!(acc.m().to_bits(), real.m().to_bits(), "{label}: max drift");
        assert_eq!(acc.l().to_bits(), real.l().to_bits(), "{label}: denominator drift");
        let mut got = vec![0.0f32; d];
        acc.finalize_into(&mut c, &mut got);
        assert_eq!(got, real_out, "{label}: identity combine changed the row");
    }

    // An empty chunk inserted into the merge tree does not perturb the
    // result (the tree pairs it away as an identity).
    let chunks: [&[usize]; 2] = [&keys[..9], &keys[9..]];
    let two: Vec<SoftmaxPartial> = chunks
        .iter()
        .map(|ch| {
            let red = ReductionOrder::Strict;
            run_partition(q.row(0), &k, &v, ch, scale, 7, red, KernelPath::Scalar).0
        })
        .collect();
    let mut with_empty = vec![two[0].clone(), SoftmaxPartial::empty(d), two[1].clone()];
    let mut without = two;
    let m1 = merge_partials_tree(&mut without, &mut c);
    let mut out1 = vec![0.0f32; d];
    m1.finalize_into(&mut c, &mut out1);
    let m2 = merge_partials_tree(&mut with_empty, &mut c);
    let mut out2 = vec![0.0f32; d];
    m2.finalize_into(&mut c, &mut out2);
    assert_eq!(out1, out2, "an empty shard perturbed the merge");

    // A single-key partition is the exact softmax of one key: out = V row.
    let (_, single) = run_partition(
        q.row(0),
        &k,
        &v,
        &[13],
        scale,
        7,
        ReductionOrder::Strict,
        KernelPath::Scalar,
    );
    assert_eq!(single.as_slice(), v.row(13), "single-key softmax must return its V row");

    // A single-row "matrix" round-trips through the SU-FA comparison.
    let one_key_rows = vec![vec![13usize]];
    let inp = AttnInputs::new(&q, &k, &v);
    let mut want = Mat::zeros(1, d);
    let p = SufaParams { bc: 7, order: UpdateOrder::Ascend, reduction: ReductionOrder::Strict };
    sufa_attention_rows_into_with(
        &inp,
        &one_key_rows,
        &p,
        &mut c,
        &mut SufaScratch::default(),
        &mut want,
        KernelPath::Scalar,
    );
    assert_eq!(single.as_slice(), want.row(0));
}

#[test]
fn random_partitions_match_the_monolithic_reduction() {
    let (t, s, d) = (6usize, 96usize, 16usize);
    let (q, k, v) = mats(t, s, d, 7);
    let scale = 1.0 / (d as f32).sqrt();
    let mut rng = Rng::new(8);
    let rows = random_rows(t, s, &mut rng);
    for (i, keys) in rows.iter().enumerate() {
        let (_, exact) = run_partition(
            q.row(i),
            &k,
            &v,
            keys,
            scale,
            7,
            ReductionOrder::Strict,
            KernelPath::Scalar,
        );
        for w in [1usize, 2, 3, 5, 8] {
            // Random (non-contiguous) assignment of each key to a shard,
            // preserving each shard's relative visit order.
            let mut chunks: Vec<Vec<usize>> = vec![Vec::new(); w];
            for &key in keys {
                chunks[rng.below(w)].push(key);
            }
            let mut parts: Vec<SoftmaxPartial> = chunks
                .iter()
                .map(|ch| {
                    run_partition(
                        q.row(i),
                        &k,
                        &v,
                        ch,
                        scale,
                        7,
                        ReductionOrder::Strict,
                        KernelPath::Scalar,
                    )
                    .0
                })
                .collect();
            let mut c = OpCounter::new();
            let merged = merge_partials_tree(&mut parts, &mut c);
            let mut got = vec![0.0f32; d];
            merged.finalize_into(&mut c, &mut got);
            if w == 1 {
                // One partition is the monolithic reduction, bitwise.
                assert_eq!(got, exact, "row {i}: w=1 must be exact");
            } else {
                let dev = got
                    .iter()
                    .zip(&exact)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(
                    dev <= 5e-5,
                    "row {i} w={w}: combine deviation {dev} beyond f32 rescale precision"
                );
            }
        }
    }
}
