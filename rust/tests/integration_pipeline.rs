//! Integration: the sparse-attention pipeline subsystem.
//!
//! The two parity anchors of the refactor:
//! 1. keep = 1.0 with the dense formal kernel reproduces `dense_attention`
//!    (the pipeline is a strict generalization of dense attention);
//! 2. tiled execution equals untiled stage-by-stage execution for the
//!    full DLZS + SADS + SU-FA stack (cross-stage tiling changes the
//!    schedule, never the math).

use star::arith::OpCounter;
use star::attention::{dense_attention, masked_attention_oracle, AttnInputs};
use star::config::ModelConfig;
use star::pipeline::{PipelineConfig, PipelineInputs, SparseAttentionPipeline};
use star::util::Rng;
use star::workload::AttnWorkload;

fn workload(t: usize, s: usize, seed: u64) -> AttnWorkload {
    let model = ModelConfig::preset("tiny").unwrap();
    let mut rng = Rng::new(seed);
    AttnWorkload::generate(&model, s, t, &mut rng)
}

#[test]
fn keep_one_dense_formal_matches_dense_attention() {
    for (t, s, seed) in [(16usize, 64usize, 1u64), (33, 127, 2), (8, 256, 3)] {
        let wl = workload(t, s, seed);
        let pipe = SparseAttentionPipeline::new(PipelineConfig::dense_oracle().with_tile(9));
        let r = pipe.run(&PipelineInputs::qkv(&wl.q, &wl.k, &wl.v));
        let inp = AttnInputs::new(&wl.q, &wl.k, &wl.v);
        let mut c = OpCounter::new();
        let dense = dense_attention(&inp, usize::MAX, &mut c);
        let err = r.out.max_abs_diff(&dense);
        assert!(err < 1e-5, "t={t} s={s}: dense parity err {err}");
        assert_eq!(r.keep, s);
        assert_eq!(r.density(s), 1.0);
    }
}

#[test]
fn tiled_equals_untiled_for_full_star_stack() {
    // DLZS prediction + SADS top-k + SU-FA, with on-demand KV: every
    // tile size and thread count must produce the identical selection
    // and output (prediction operands are prepared globally).
    for seed in [11u64, 12, 13] {
        let wl = workload(48, 160, seed);
        let inputs = PipelineInputs::from_workload(&wl);
        let cfg = PipelineConfig::star().with_keep(0.25);
        let whole =
            SparseAttentionPipeline::new(cfg.with_tile(48).with_threads(1)).run(&inputs);
        for (tile_t, threads) in [(4usize, 1usize), (7, 4), (16, 2), (48, 3)] {
            let tiled = SparseAttentionPipeline::new(cfg.with_tile(tile_t).with_threads(threads))
                .run(&inputs);
            assert_eq!(
                tiled.selection, whole.selection,
                "seed={seed} tile={tile_t} threads={threads}: selection drift"
            );
            assert_eq!(
                tiled.out.max_abs_diff(&whole.out),
                0.0,
                "seed={seed} tile={tile_t} threads={threads}: output drift"
            );
            // Predict and top-k accounting is schedule-independent;
            // formal *compute* ops are per-row and match exactly. (KV-gen
            // work and KV traffic legitimately grow with finer tiles — a
            // key regenerates once per selecting tile.)
            assert_eq!(tiled.ops.predict, whole.ops.predict, "predict accounting drift");
            assert_eq!(tiled.ops.topk, whole.ops.topk, "topk accounting drift");
            let (a, b) = (&tiled.ops.formal, &whole.ops.formal);
            assert_eq!(
                (a.mul, a.add, a.cmp, a.exp, a.div),
                (b.mul, b.add, b.cmp, b.exp, b.div),
                "formal compute drift"
            );
        }
    }
}

#[test]
fn pipeline_output_is_exact_softmax_over_its_selection() {
    let wl = workload(24, 192, 21);
    let r = SparseAttentionPipeline::star(0.2).run(&PipelineInputs::from_workload(&wl));
    let inp = AttnInputs::new(&wl.q, &wl.k, &wl.v);
    let oracle = masked_attention_oracle(&inp, &r.selection);
    let err = r.out.max_abs_diff(&oracle);
    assert!(err < 1e-4, "masked-oracle parity err {err}");
}

#[test]
fn sparse_output_tracks_dense_oracle() {
    // Structured (Type I/II) scores are where top-k sparsity is safe; on
    // the tiny workload the standard config must stay within a loose
    // relative error of dense.
    let wl = workload(32, 256, 31);
    let r = SparseAttentionPipeline::star(0.25).run(&PipelineInputs::from_workload(&wl));
    let inp = AttnInputs::new(&wl.q, &wl.k, &wl.v);
    let mut c = OpCounter::new();
    let dense = dense_attention(&inp, usize::MAX, &mut c);
    let rel = r.out.rel_err(&dense);
    assert!(rel < 0.9, "sparse vs dense rel err {rel}");
}

#[test]
fn config_vocabulary_is_shared_with_the_simulator() {
    // A pipeline config drives the cycle-level simulator directly.
    use star::config::AccelConfig;
    use star::sim::dram::DramChannel;
    use star::sim::pipeline::{simulate, WorkloadShape};
    let cfg = PipelineConfig::star();
    // The LTPP regime (T = 512), where the baseline's spills dominate.
    let shape = WorkloadShape::new(512, 2048, 64, 768, cfg.keep_ratio);
    let star = simulate(&shape, &cfg.feature_set(), &AccelConfig::default(), &DramChannel::accel_256());
    let base = simulate(
        &shape,
        &PipelineConfig::ds_baseline().feature_set(),
        &AccelConfig::default(),
        &DramChannel::accel_256(),
    );
    assert!(star.total_s < base.total_s, "shared-config sim: STAR must beat the DS baseline");
}
