//! Property: span tracing is invisible to the math and free of heap
//! traffic on the warm hot path.
//!
//! The tracer (`star::obs::trace`) records fixed-size span records into
//! per-worker rings that live inside the pooled
//! [`star::pipeline::TileWorkspace`], so two contracts must hold:
//!
//! 1. **Bit-invisibility.** Outputs, selections, stalls and per-stage
//!    op counters of all three execution paths (batch prefill,
//!    autoregressive decode, sequence-sharded prefill) are identical
//!    with tracing off and with tracing on — recording is a pure
//!    index-write, never a branch into different numerics.
//! 2. **Zero-allocation recording.** This binary installs the counting
//!    allocator, so `hot_path_allocs` is a real measurement: with
//!    tracing enabled, warm traced runs must still meter zero heap
//!    allocations inside the stage cores (the ring is reserved in the
//!    unmetered preamble; see `SpanRing::reserve_if_enabled`).
//!
//! The traced phase deliberately never disables tracing afterwards:
//! the flag is process-global and other tests may assert that enabled
//! tracing records. The disabled baseline therefore runs *first*,
//! inside the one test that flips the flag.

#[global_allocator]
static ALLOC: star::util::allocmeter::CountingAllocator =
    star::util::allocmeter::CountingAllocator;

use star::kvcache::{SessionConfig, SessionStore};
use star::obs::{ExecPath, Stage};
use star::pipeline::{
    PipelineConfig, PipelineInputs, ShardedPipeline, SparseAttentionPipeline, WorkspacePool,
};
use star::tensor::Mat;
use star::util::{allocmeter, Rng};

fn mats(t: usize, s: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
    let mut rng = Rng::new(seed);
    (
        Mat::randn(t, d, 1.0, &mut rng),
        Mat::randn(s, d, 1.0, &mut rng),
        Mat::randn(s, d, 1.0, &mut rng),
    )
}

fn sub(m: &Mat, lo: usize, hi: usize) -> Mat {
    Mat::from_fn(hi - lo, m.cols, |i, j| m.at(lo + i, j))
}

#[test]
fn counting_allocator_is_live_in_this_binary() {
    let a0 = allocmeter::thread_allocs();
    let v: Vec<u64> = Vec::with_capacity(64);
    assert!(allocmeter::thread_allocs() > a0, "allocation meter must count");
    assert!(allocmeter::installed());
    drop(v);
}

/// One decode session: an 8-token prefill chunk then 8 single-token
/// steps, returning per-step outputs, selections and the hot-path
/// alloc sum of the *steps* (the prefill chunk warms the workspaces).
fn decode_session(
    cfg: PipelineConfig,
    q: &Mat,
    k: &Mat,
    v: &Mat,
    pool: &WorkspacePool,
) -> (Vec<Mat>, Vec<star::attention::Selection>, u64) {
    let d = q.cols;
    let pipe = SparseAttentionPipeline::new(cfg);
    let mut store = SessionStore::new(SessionConfig::for_pipeline(&cfg, d, 0));
    pipe.decode_step_pooled(&mut store, 1, &sub(q, 0, 8), &sub(k, 0, 8), &sub(v, 0, 8), pool)
        .expect("prefill chunk");
    let (mut outs, mut sels, mut allocs) = (Vec::new(), Vec::new(), 0u64);
    for lo in 8..16 {
        let r = pipe
            .decode_step_pooled(
                &mut store,
                1,
                &sub(q, lo, lo + 1),
                &sub(k, lo, lo + 1),
                &sub(v, lo, lo + 1),
                pool,
            )
            .expect("decode step");
        allocs += r.hot_path_allocs;
        outs.push(r.out);
        sels.push(r.selection);
    }
    (outs, sels, allocs)
}

#[test]
fn tracing_is_bit_invisible_and_allocation_free() {
    let cfg = PipelineConfig::star().with_keep(0.25).with_tile(8).with_threads(1);
    let (q, k, v) = mats(24, 128, 16, 42);
    let inputs = PipelineInputs::qkv(&q, &k, &v);
    let pipe = SparseAttentionPipeline::new(cfg);
    let sharded = ShardedPipeline::new(cfg, 2);

    // ---- Baseline, tracing disabled (the process default; this is the
    // only test in this binary that flips the flag). ----
    assert!(!star::obs::enabled(), "tracing must start disabled in this binary");
    let pool_off = WorkspacePool::new();
    let base_prefill = pipe.run_pooled(&inputs, &pool_off);
    let base_sharded = sharded.run_pooled(&inputs, &pool_off);
    let (base_outs, base_sels, _) = decode_session(cfg, &q, &k, &v, &pool_off);
    let mut none = Vec::new();
    pool_off.drain_spans(&mut none);
    assert!(none.is_empty(), "disabled tracing must record nothing");

    // ---- Traced: same workload on a fresh pool. First passes run on
    // cold workspaces (warm-up, allocs uncounted); second passes are the
    // measurement. ----
    star::obs::set_enabled(true);
    let pool_on = WorkspacePool::new();
    pipe.run_pooled(&inputs, &pool_on);
    sharded.run_pooled(&inputs, &pool_on);
    let mut warmup = Vec::new();
    pool_on.drain_spans(&mut warmup);
    assert!(!warmup.is_empty(), "enabled tracing must record spans");

    let traced_prefill = pipe.run_pooled(&inputs, &pool_on);
    let traced_sharded = sharded.run_pooled(&inputs, &pool_on);
    let (traced_outs, traced_sels, decode_allocs) = decode_session(cfg, &q, &k, &v, &pool_on);

    // 1. Bit-invisibility.
    assert_eq!(traced_prefill.out.max_abs_diff(&base_prefill.out), 0.0, "prefill output drift");
    assert_eq!(traced_prefill.selection, base_prefill.selection, "prefill selection drift");
    assert_eq!(traced_prefill.stalls, base_prefill.stalls, "prefill stall drift");
    assert_eq!(traced_prefill.ops.predict, base_prefill.ops.predict, "prefill predict ops drift");
    assert_eq!(traced_prefill.ops.formal, base_prefill.ops.formal, "prefill formal ops drift");
    assert_eq!(traced_sharded.out.max_abs_diff(&base_sharded.out), 0.0, "sharded output drift");
    assert_eq!(traced_sharded.selection, base_sharded.selection, "sharded selection drift");
    assert_eq!(traced_outs.len(), base_outs.len());
    for (i, (t, b)) in traced_outs.iter().zip(&base_outs).enumerate() {
        assert_eq!(t.max_abs_diff(b), 0.0, "decode step {i} output drift");
    }
    assert_eq!(traced_sels, base_sels, "decode selection drift");

    // 2. Zero-allocation recording on the warm hot path.
    assert_eq!(traced_prefill.hot_path_allocs, 0, "traced warm prefill allocated");
    assert_eq!(traced_sharded.hot_path_allocs, 0, "traced warm sharded run allocated");
    assert_eq!(decode_allocs, 0, "traced warm decode steps allocated");

    // The traced passes recorded every stage on every path.
    let mut spans = Vec::new();
    pool_on.drain_spans(&mut spans);
    let have = |st: Stage, p: ExecPath| spans.iter().any(|s| s.stage == st && s.path == p);
    for st in [Stage::Predict, Stage::Topk, Stage::KvGen, Stage::Formal] {
        for p in [ExecPath::Prefill, ExecPath::Decode, ExecPath::Sharded] {
            assert!(have(st, p), "missing {} span on the {} path", st.name(), p.name());
        }
    }
    assert!(have(Stage::Ring, ExecPath::Sharded), "missing sharded ring spans");
    assert!(have(Stage::Merge, ExecPath::Sharded), "missing sharded merge spans");
    for s in &spans {
        assert!(s.end_ns >= s.start_ns, "span time went backwards");
    }
}
