//! Integration: the full single-core simulation stack — workload
//! generation → prediction → top-k → SU-FA → cycle/energy model — and
//! the consistency between the algorithm layer and the simulator.

use star::arith::{EquivWeights, OpCounter};
use star::attention::{dense_attention, sufa_attention, AttnInputs, Selection, SufaParams};
use star::config::{AccelConfig, ModelConfig};
use star::sim::baselines::Baseline;
use star::sim::dram::DramChannel;
use star::sim::pipeline::{simulate, FeatureSet, WorkloadShape};
use star::sparsity::topk::{sads_topk, SadsParams};
use star::sparsity::{PredictScheme, Predictor};
use star::util::Rng;
use star::workload::AttnWorkload;

/// The whole algorithm pipeline on a real workload stays numerically
/// close to dense attention at a moderate keep ratio.
#[test]
fn pipeline_end_to_end_numerics() {
    let m = ModelConfig::preset("gpt2").unwrap();
    let mut rng = Rng::new(99);
    let wl = AttnWorkload::generate(&m, 256, 64, &mut rng);
    let inp = AttnInputs::new(&wl.q, &wl.k, &wl.v);
    let pred = Predictor::new(PredictScheme::Dlzs, 7);
    let mut c = OpCounter::new();
    let mut est = pred.approx_scores(&wl.q, &wl.k, &mut c);
    est.scale(1.0 / (wl.q.cols as f32).sqrt());
    let keep = 128; // 50% of 256
    let mut rows = Vec::new();
    for i in 0..est.rows {
        let (idx, _) = sads_topk(est.row(i), keep, &SadsParams::default(), &mut c);
        rows.push(idx);
    }
    let sel = Selection { rows };
    let r = sufa_attention(&inp, &sel, &SufaParams::default(), &mut c);
    let mut cd = OpCounter::new();
    let dense = dense_attention(&inp, usize::MAX, &mut cd);
    let rel = r.out.rel_err(&dense);
    assert!(rel < 0.35, "pipeline rel err {rel}");
    // And it must be cheaper in equivalent adds than dense.
    let ew = EquivWeights::default();
    assert!(c.equivalent_adds(&ew) < cd.equivalent_adds(&ew));
}

/// Simulator consistency: STAR beats the dense ASIC (the same-scope
/// in-job comparison: both generate KV on their own PE array) on both
/// latency and energy, for every model in the suite.
#[test]
fn feature_ladder_monotone_for_suite() {
    let cfg = AccelConfig::default();
    let dram = DramChannel::accel_256();
    for m in ModelConfig::suite() {
        let shape = WorkloadShape::new(128, m.seq_len.min(2048), m.head_dim(), m.hidden, 0.2);
        let star = simulate(&shape, &FeatureSet::star(), &cfg, &dram);
        let dense = simulate(&shape, &FeatureSet::dense_asic(), &cfg, &dram);
        assert!(
            star.total_s < dense.total_s,
            "{}: star {} !< dense {}",
            m.name,
            star.total_s,
            dense.total_s
        );
        assert!(star.energy.total_j() < dense.energy.total_j(), "{}", m.name);
    }
}

/// Energy accounting is internally consistent: breakdown parts sum to
/// the total, and all are non-negative.
#[test]
fn energy_breakdown_consistent() {
    let cfg = AccelConfig::default();
    let dram = DramChannel::accel_256();
    let r = simulate(&WorkloadShape::new(128, 2048, 64, 768, 0.2), &FeatureSet::star(), &cfg, &dram);
    let e = r.energy;
    assert!(e.compute_j >= 0.0 && e.sram_j >= 0.0 && e.dram_j >= 0.0);
    assert!((e.compute_j + e.sram_j + e.dram_j - e.total_j()).abs() < 1e-12);
    assert!(r.total_s > 0.0 && r.eff_gops > 0.0);
}

/// Every behavioral baseline simulates without panicking across a grid
/// of shapes, and reports sane numbers.
#[test]
fn baseline_grid_sane() {
    let dram = DramChannel::accel_256();
    for b in [Baseline::Fact, Baseline::Energon, Baseline::Elsa, Baseline::Spatten, Baseline::Simba] {
        for t in [1usize, 32, 256] {
            for s in [128usize, 1024] {
                let shape = WorkloadShape::new(t, s, 64, 768, 0.25);
                let r = simulate(&shape, &b.features(), &b.config(), &dram);
                assert!(r.total_s.is_finite() && r.total_s > 0.0, "{} t={t} s={s}", b.name());
                assert!(r.mat_fraction() >= 0.0 && r.mat_fraction() <= 1.0);
            }
        }
    }
}
