//! Minimal offline shim of the `anyhow` API surface this workspace uses:
//! [`Error`], [`Result`], and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The real crate is unavailable in the offline build environment; this
//! shim keeps the call sites source-compatible. Like the real `anyhow`,
//! [`Error`] deliberately does **not** implement `std::error::Error`, so
//! the blanket `From<E: std::error::Error>` conversion stays coherent and
//! `?` works on `io::Error` & friends.

use std::fmt;

/// A string-backed error value with an optional cause chain rendered into
/// the message at conversion time.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    /// Attach context, mirroring `anyhow::Error::context` semantics
    /// (context first, original error after).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` on real anyhow prints the cause chain; the shim keeps the
        // chain inline in the message, so both render the same string.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` alias with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/nonexistent/anyhow-shim-test")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        let f = || -> Result<()> { bail!("stop {}", "now") };
        assert_eq!(f().unwrap_err().to_string(), "stop now");
        let g = |x: i32| -> Result<()> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(())
        };
        assert!(g(1).is_ok());
        assert_eq!(g(-1).unwrap_err().to_string(), "x must be positive, got -1");
    }

    #[test]
    fn context_prepends() {
        let e = Error::msg("root").context("while loading");
        assert_eq!(e.to_string(), "while loading: root");
    }
}
