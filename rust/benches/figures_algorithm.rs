//! `cargo bench --bench figures_algorithm` — regenerates: fig9 fig11 fig16 fig17 fig18 table2.
//! Plain main (criterion is unavailable offline); prints the paper's
//! rows/series plus wall time per figure.

fn main() {
    for name in ["fig9", "fig11", "fig16", "fig17", "fig18", "table2", ] {
        let t0 = std::time::Instant::now();
        star::bench::run(name).unwrap();
        println!("[{name} regenerated in {:?}]", t0.elapsed());
    }
}
