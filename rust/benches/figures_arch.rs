//! `cargo bench --bench figures_arch` — regenerates: fig19 fig20 fig21 fig22 fig23 table3.
//! Plain main (criterion is unavailable offline); prints the paper's
//! rows/series plus wall time per figure.

fn main() {
    for name in ["fig19", "fig20", "fig21", "fig22", "fig23", "table3", ] {
        let t0 = std::time::Instant::now();
        star::bench::run(name).unwrap();
        println!("[{name} regenerated in {:?}]", t0.elapsed());
    }
}
