//! `cargo bench --bench tables` — regenerates: table2 table3.
//! Plain main (criterion is unavailable offline); prints the paper's
//! rows/series plus wall time per figure.

fn main() {
    for name in ["table2", "table3", ] {
        let t0 = std::time::Instant::now();
        star::bench::run(name).unwrap();
        println!("[{name} regenerated in {:?}]", t0.elapsed());
    }
}
