//! `cargo bench --bench figures_spatial` — regenerates: fig24 plus the
//! measured sequence-sharded study (spatial-exec).
//! Plain main (criterion is unavailable offline); prints the paper's
//! rows/series plus wall time per figure.

fn main() {
    for name in ["fig24", "spatial-exec"] {
        let t0 = std::time::Instant::now();
        star::bench::run(name).unwrap();
        println!("[{name} regenerated in {:?}]", t0.elapsed());
    }
}
