//! `cargo bench --bench decode` — decode throughput on the paged
//! KV-cache (writes `BENCH_decode.json` at the repo root).
//! Plain main (criterion is unavailable offline).

fn main() {
    let t0 = std::time::Instant::now();
    star::bench::run("decode").unwrap();
    println!("[decode bench in {:?}]", t0.elapsed());
}
