//! `cargo bench --bench decode` — decode throughput on the paged
//! KV-cache (writes `BENCH_decode.json` at the repo root).
//! Plain main (criterion is unavailable offline).

// Count allocations so the bench's `hot_path_allocs` field is a real
// measurement (the zero-allocation regression guard).
#[global_allocator]
static ALLOC: star::util::allocmeter::CountingAllocator =
    star::util::allocmeter::CountingAllocator;

fn main() {
    let t0 = std::time::Instant::now();
    star::bench::run("decode").unwrap();
    println!("[decode bench in {:?}]", t0.elapsed());
}
