//! `cargo bench --bench figures_motivation` — regenerates: fig1 fig3 fig4 fig5 fig7.
//! Plain main (criterion is unavailable offline); prints the paper's
//! rows/series plus wall time per figure.

fn main() {
    for name in ["fig1", "fig3", "fig4", "fig5", "fig7", ] {
        let t0 = std::time::Instant::now();
        star::bench::run(name).unwrap();
        println!("[{name} regenerated in {:?}]", t0.elapsed());
    }
}
