#!/usr/bin/env python3
"""Independent cross-reader for BENCH_traffic.json.

CI runs this after `star bench traffic`. The Rust bench already
hard-fails in-process when measured traffic diverges from the
simulator's prediction; this script re-validates the *written artifact*
with none of the Rust code in the loop:

  1. schema — every counter field, scheduler stat, modeled figure and
     per-stage check the document promises is present and well-typed;
  2. tolerance — each stage's |measured - modeled| elements is re-derived
     here and checked against max(abs_elems, rel * modeled), using the
     tolerances the document itself declares;
  3. invariants — zero hot-path allocations (per path and overall, with
     the allocation counter attested live), ring traffic only on the
     sharded path, and class counters partitioning the total.

stdlib only; exits non-zero with a per-violation message on any failure.
"""

import json
import sys

PATHS = ("prefill", "decode", "sharded")
STAGES = ("predict", "topk", "kv_gen", "formal")
# Must match TrafficCounter::fields() (rust/src/obs/traffic.rs).
MEASURED_FIELDS = (
    "q_ingest_bytes",
    "key_ingest_bytes",
    "x_ingest_bytes",
    "out_egress_bytes",
    "score_write_bytes",
    "score_read_bytes",
    "operand_read_bytes",
    "kv_gather_bytes",
    "formal_kv_bytes",
    "accum_bytes",
    "ring_payload_bytes",
    "cache_append_bytes",
    "cache_remat_bytes",
)
SCHED_FIELDS = ("workers", "chunk_grabs", "steals", "tiles", "max_worker_tiles", "imbalance")
MODELED_FIELDS = (
    "predict_dram_bytes",
    "topk_dram_bytes",
    "kv_gen_dram_bytes",
    "formal_dram_bytes",
    "total_dram_bytes",
    "kv_resident_bytes",
)
SHAPE_FIELDS = ("t", "s", "d", "h", "keep_ratio", "union_ratio")


def num(doc, where, key):
    v = doc.get(key)
    if not isinstance(v, (int, float)) or isinstance(v, bool):
        raise SystemExit(f"FAIL {where}.{key}: missing or non-numeric ({v!r})")
    return float(v)


def check_path(name, p, rel, abs_elems):
    where = f"paths.{name}"
    for section, fields in (
        ("shape", SHAPE_FIELDS),
        ("measured", MEASURED_FIELDS + ("dram_class_bytes", "sram_class_bytes")),
        ("sched", SCHED_FIELDS),
        ("modeled", MODELED_FIELDS),
    ):
        obj = p.get(section)
        if not isinstance(obj, dict):
            raise SystemExit(f"FAIL {where}.{section}: missing object")
        for f in fields:
            num(obj, f"{where}.{section}", f)

    m = p["measured"]
    total = sum(num(m, f"{where}.measured", f) for f in MEASURED_FIELDS)
    classes = (
        num(m, f"{where}.measured", "dram_class_bytes")
        + num(m, f"{where}.measured", "sram_class_bytes")
        + m["ring_payload_bytes"]
        + m["cache_append_bytes"]
        + m["cache_remat_bytes"]
    )
    if total != classes:
        raise SystemExit(
            f"FAIL {where}: class counters do not partition the total "
            f"({classes} classed vs {total} summed)"
        )
    if total <= 0:
        raise SystemExit(f"FAIL {where}: no traffic measured at all")
    ring = m["ring_payload_bytes"]
    if name == "sharded" and ring <= 0:
        raise SystemExit(f"FAIL {where}: sharded path measured no ring traffic")
    if name != "sharded" and ring != 0:
        raise SystemExit(f"FAIL {where}: non-sharded path measured ring traffic ({ring})")

    stages = p.get("stages")
    if not isinstance(stages, dict):
        raise SystemExit(f"FAIL {where}.stages: missing object")
    for stage in STAGES:
        c = stages.get(stage)
        if not isinstance(c, dict):
            raise SystemExit(f"FAIL {where}.stages.{stage}: missing object")
        measured = num(c, f"{where}.stages.{stage}", "measured_elems")
        modeled = num(c, f"{where}.stages.{stage}", "modeled_elems")
        num(c, f"{where}.stages.{stage}", "ratio")
        tol = max(abs_elems, rel * modeled)
        if abs(measured - modeled) > tol:
            raise SystemExit(
                f"FAIL {where}.stages.{stage}: measured {measured:.1f} vs modeled "
                f"{modeled:.1f} elements exceeds tolerance {tol:.1f}"
            )

    if num(p, where, "hot_path_allocs") != 0:
        raise SystemExit(f"FAIL {where}: hot-path allocations metered on counted warm run")
    if num(p["sched"], f"{where}.sched", "imbalance") < 1.0 - 1e-9:
        raise SystemExit(f"FAIL {where}.sched: imbalance below 1.0")


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_traffic.json"
    with open(path) as f:
        doc = json.load(f)

    if doc.get("bench") != "traffic":
        raise SystemExit(f"FAIL bench: expected 'traffic', got {doc.get('bench')!r}")
    tol = doc.get("tolerance")
    if not isinstance(tol, dict):
        raise SystemExit("FAIL tolerance: missing object")
    rel = num(tol, "tolerance", "rel")
    abs_elems = num(tol, "tolerance", "abs_elems")
    if not (0 < rel < 1) or abs_elems < 0:
        raise SystemExit(f"FAIL tolerance: implausible bounds rel={rel} abs_elems={abs_elems}")

    paths = doc.get("paths")
    if not isinstance(paths, dict):
        raise SystemExit("FAIL paths: missing object")
    for name in PATHS:
        p = paths.get(name)
        if not isinstance(p, dict):
            raise SystemExit(f"FAIL paths.{name}: missing object")
        check_path(name, p, rel, abs_elems)

    if num(doc, "<root>", "hot_path_allocs") != 0:
        raise SystemExit("FAIL hot_path_allocs: counted warm runs allocated")
    if doc.get("alloc_counter_on") is not True:
        raise SystemExit("FAIL alloc_counter_on: allocation meter was not live")

    print(f"OK {path}: {len(PATHS)} paths x {len(STAGES)} stages within tolerance, 0 hot-path allocs")


if __name__ == "__main__":
    main()
